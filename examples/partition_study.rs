//! Tables 1-2 + Figure 2 at multiple scales, plus the Table 5 partition
//! statistics — everything about partition quality that needs no XLA.
//!
//! Includes the paper-scale `fb-syn` (14,541 entities / 272k edges,
//! FB15k-237's exact shape) and a 100k-vertex citation graph: partition
//! statistics are cheap even where training is not, so the RF trends of
//! the paper's Table 2 are reproduced at full scale here.
//!
//! Run: `cargo run --release --example partition_study`

use kgscale::config::{DatasetConfig, DatasetKind, ExperimentConfig, PartitionStrategy};
use kgscale::experiments;
use kgscale::graph::generator;
use kgscale::partition::{self, stats as pstats};
use kgscale::report::{save_report, Table};

fn main() -> anyhow::Result<()> {
    let mut out = String::new();

    // Paper-scale FB15k-237 stand-in (Table 1/2 left column).
    let fb_syn = DatasetConfig {
        name: "fb-syn (FB15k-237 scale)".into(),
        kind: DatasetKind::ZipfKg,
        entities: 14_541,
        relations: 237,
        train_edges: 272_115,
        valid_edges: 17_535,
        test_edges: 20_466,
        feature_dim: 0,
        zipf_exponent: 1.1,
        seed: 42,
    };
    // Larger citation-style graph (Table 1/2 right column, scaled 1:30).
    let cite_syn = DatasetConfig {
        name: "cite-syn (citation2 / 30)".into(),
        kind: DatasetKind::Citation,
        entities: 100_000,
        relations: 1,
        train_edges: 1_000_000,
        valid_edges: 3_000,
        test_edges: 3_000,
        feature_dim: 0, // features irrelevant for partition stats
        zipf_exponent: 1.0,
        seed: 42,
    };

    println!("generating fb-syn...");
    let g_fb = generator::generate(&fb_syn);
    println!("generating cite-syn...");
    let g_cite = generator::generate(&cite_syn);

    out.push_str(&experiments::table1(&[&g_fb, &g_cite]).to_markdown());

    // Table 2: HDRF + 2-hop NE across partition counts, both datasets.
    let cfg = ExperimentConfig::tiny(); // partition params only
    for g in [&g_fb, &g_cite] {
        let t = experiments::table2(&cfg, g, &[2, 4, 8]);
        out.push_str(&t.to_markdown());
    }

    // Table 5 statistics (partitioner comparison at P=4) on cite-syn.
    let mut t5 = Table::new(
        "Table 5 (stats): partitioning strategies, 4 partitions, cite-syn",
        &["Partitioning", "# core edges", "# total edges", "RF", "core-RF", "balance"],
    );
    for (label, strategy) in [
        ("HDRF+NE (KaHIP-sub)", PartitionStrategy::Hdrf),
        ("DBH+NE", PartitionStrategy::Dbh),
        ("Greedy-VP+NE (Metis-sub)", PartitionStrategy::MetisLike),
        ("Random+NE", PartitionStrategy::Random),
    ] {
        let pcfg = kgscale::config::PartitionConfig {
            strategy,
            num_partitions: 4,
            ..Default::default()
        };
        let parts = partition::partition_graph(&g_cite, &pcfg, 42);
        let s = pstats::compute(&parts, g_cite.num_entities);
        t5.row(vec![
            label.into(),
            s.core_cell(),
            s.total_cell(),
            format!("{:.2}", s.replication_factor),
            format!("{:.2}", s.core_replication_factor),
            format!("{:.2}", s.balance_ratio),
        ]);
        println!("{label}: done");
    }
    out.push_str(&t5.to_markdown());

    // Figure 2: avg vertices per n-hop embedding on the citation graph.
    let fig = experiments::fig2(&cfg, &g_cite, 3);
    out.push_str(&fig.to_ascii());
    out.push_str(&fig.to_csv());

    println!("{out}");
    let path = save_report("partition_study.md", &out)?;
    println!("saved {path:?}");
    Ok(())
}

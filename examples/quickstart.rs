//! Quickstart: the whole system in ~60 lines.
//!
//! Generates the tiny synthetic KG, partitions it for 2 trainers
//! (vertex-cut + neighborhood expansion), trains the RGCN+DistMult model
//! through the AOT artifacts for a few epochs, and reports filtered MRR.
//!
//! Run: `make artifacts && cargo run --release --example quickstart`

use kgscale::config::ExperimentConfig;
use kgscale::eval::{self, FilterIndex};
use kgscale::graph::generator;
use kgscale::model::Manifest;
use kgscale::runtime::Runtime;
use kgscale::train::Trainer;
use std::path::Path;

fn main() -> anyhow::Result<()> {
    // 1. Dataset: FB15k-237-style synthetic KG (300 entities, 8 relations).
    let mut cfg = ExperimentConfig::tiny();
    cfg.train.num_trainers = 2;
    let graph = generator::generate(&cfg.dataset);
    println!(
        "dataset: {} entities, {} relations, {} train edges",
        graph.num_entities,
        graph.num_relations,
        graph.train.len()
    );

    // 2. Runtime: load the AOT-compiled artifacts (HLO text -> PJRT CPU).
    let dir = Path::new("artifacts/tiny");
    let manifest = Manifest::load(dir)?;
    let runtime = Runtime::new(dir)?;
    println!("artifacts: {} parameters, {} entries", manifest.param_count, manifest.entries.len());

    // 3. Trainer: partitions the graph (HDRF vertex-cut + 2-hop expansion)
    //    and runs synchronous data-parallel training with ring AllReduce.
    let mut trainer = Trainer::new(cfg, &graph, &runtime, manifest.clone())?;
    println!("workers: {:?} core edges each", trainer.worker_core_edges());
    for epoch in 0..20 {
        let rec = trainer.train_epoch()?;
        if epoch % 5 == 0 || epoch == 19 {
            println!(
                "epoch {epoch:>2}: loss={:.4} cluster-epoch-time={:.3}s",
                rec.mean_loss, rec.virtual_secs
            );
        }
    }

    // 4. Evaluate: filtered MRR / Hits@k on the test split. Set
    //    `eval.host_threads > 0` to rank chunks on a host pool while the
    //    next chunk's scores execute (bit-identical metrics either way).
    let filter = FilterIndex::build(&graph)?;
    let ecfg = kgscale::config::EvalConfig { host_threads: 2, prefetch_depth: 2 };
    let mut evaluator = eval::Evaluator::new(&manifest, &graph, &ecfg)?;
    let (m, stats) =
        evaluator.evaluate(&runtime, &manifest, &trainer.params, &filter, &graph.test)?;
    println!(
        "test: MRR={:.4} Hits@1={:.4} Hits@10={:.4} ({} ranked queries, eval {:.3}s)",
        m.mrr, m.hits1, m.hits10, m.num_queries, stats.wall_secs
    );
    Ok(())
}

//! Table 3 (left) reproduction: distributed full-edge-batch training on
//! the FB15k-237 stand-in (`fbmini`), sweeping 1/2/4/8 trainers and
//! reporting MRR / Hits@1 / epoch time / speedup.
//!
//! This is the paper's accuracy-parity experiment: distributed training
//! with constraint-based local negatives must match non-distributed MRR.
//!
//! Run: `make artifacts && cargo run --release --example train_fb15k -- [epochs]`

use kgscale::config::ExperimentConfig;
use kgscale::experiments;
use kgscale::model::Manifest;
use kgscale::report::save_report;
use kgscale::runtime::Runtime;
use std::path::Path;

fn main() -> anyhow::Result<()> {
    let epochs: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(12);
    let cfg = ExperimentConfig::from_file("configs/fbmini.toml")?;
    let graph = experiments::dataset(&cfg);
    let dir = Path::new("artifacts/fbmini");
    let manifest = Manifest::load(dir)?;
    let runtime = Runtime::new(dir)?;

    println!("{}", experiments::table1(&[&graph]).to_markdown());
    println!("{}", experiments::table2(&cfg, &graph, &[2, 4, 8]).to_markdown());

    let (t3, rows) = experiments::table3_sweep(
        &cfg, &graph, &runtime, &manifest, &[1, 2, 4, 8], epochs, 0, 400,
    )?;
    println!("{}", t3.to_markdown());
    let (f6a, f6b) = experiments::fig6(&rows, &graph.name);
    println!("{}", f6b.to_markdown());
    let mut out = t3.to_markdown();
    out.push_str(&f6a.to_csv());
    out.push_str(&f6b.to_markdown());
    let path = save_report("train_fb15k.md", &out)?;
    println!("saved {path:?}");
    Ok(())
}

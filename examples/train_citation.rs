//! Table 3 (right) + Figure 7 reproduction: edge mini-batch distributed
//! training on the citation-graph stand-in (`citemini`) — the paper's
//! large-graph regime where getComputeGraph dominates and the distributed
//! speedup comes from fewer, smaller batches per worker.
//!
//! Also the repo's END-TO-END VALIDATION driver (DESIGN.md): trains the
//! full three-layer stack on a realistic workload for a few hundred
//! steps, logging the loss curve and MRR-vs-time convergence.
//!
//! Run: `make artifacts && cargo run --release --example train_citation -- [epochs]`

use kgscale::config::ExperimentConfig;
use kgscale::experiments;
use kgscale::model::Manifest;
use kgscale::report::save_report;
use kgscale::runtime::Runtime;
use std::path::Path;

fn main() -> anyhow::Result<()> {
    let epochs: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(10);
    let cfg = ExperimentConfig::from_file("configs/citemini.toml")?;
    let graph = experiments::dataset(&cfg);
    let dir = Path::new("artifacts/citemini");
    let manifest = Manifest::load(dir)?;
    let runtime = Runtime::new(dir)?;

    println!("{}", experiments::table1(&[&graph]).to_markdown());

    // Convergence requires periodic eval: every ~1/5th of the run.
    let eval_every = (epochs / 5).max(1);
    let (t3, rows) = experiments::table3_sweep(
        &cfg, &graph, &runtime, &manifest, &[1, 2, 4, 8], epochs, eval_every, 300,
    )?;
    println!("{}", t3.to_markdown());

    let (f6a, f6b) = experiments::fig6(&rows, &graph.name);
    println!("{}", f6a.to_ascii());
    println!("{}", f6b.to_markdown());
    let f7 = experiments::fig7(&rows, &graph.name);
    println!("{}", f7.to_ascii());

    let mut out = t3.to_markdown();
    out.push_str(&f6a.to_csv());
    out.push_str(&f6b.to_markdown());
    out.push_str(&f7.to_csv());
    let path = save_report("train_citation.md", &out)?;
    println!("saved {path:?}");
    Ok(())
}

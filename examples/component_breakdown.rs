//! Figure 6 reproduction: average epoch time and per-batch component
//! breakdown (getComputeGraph / GNNmodel / sync+step) as the trainer
//! count grows, on the citation tier — plus the negative-sampling
//! ablation the paper motivates in §3.3.1 (local constraint-based vs
//! global sampling with simulated remote fetches).
//!
//! Run: `make artifacts && cargo run --release --example component_breakdown -- [epochs]`

use kgscale::config::ExperimentConfig;
use kgscale::experiments;
use kgscale::model::Manifest;
use kgscale::report::{save_report, Table};
use kgscale::runtime::Runtime;
use kgscale::train::Trainer;
use std::path::Path;

fn main() -> anyhow::Result<()> {
    let epochs: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(3);
    let cfg = ExperimentConfig::from_file("configs/citemini.toml")?;
    let graph = experiments::dataset(&cfg);
    let dir = Path::new("artifacts/citemini");
    let manifest = Manifest::load(dir)?;
    let runtime = Runtime::new(dir)?;

    let (_, rows) = experiments::table3_sweep(
        &cfg, &graph, &runtime, &manifest, &[1, 2, 4, 8], epochs, 0, 100,
    )?;
    let (f6a, f6b) = experiments::fig6(&rows, &graph.name);
    println!("{}", f6a.to_ascii());
    println!("{}", f6b.to_markdown());

    // Ablation: constraint-based local negatives vs global negatives.
    // Global sampling charges one simulated remote fetch per
    // out-of-partition draw (the traffic the paper's design eliminates).
    let mut ab = Table::new(
        "Ablation: negative sampling scope (4 trainers)",
        &["scope", "epoch time (virtual)", "remote fetches/epoch", "final loss"],
    );
    for (label, local) in [("local constraint-based (paper)", true), ("global", false)] {
        let mut c = cfg.clone();
        c.train.num_trainers = 4;
        c.train.local_negatives = local;
        let mut t = Trainer::new(c, &graph, &runtime, manifest.clone())?;
        let mut last = None;
        for _ in 0..epochs {
            last = Some(t.train_epoch()?);
        }
        let rec = last.unwrap();
        ab.row(vec![
            label.into(),
            format!("{:.3}s", rec.virtual_secs),
            rec.remote_fetches.to_string(),
            format!("{:.4}", rec.mean_loss),
        ]);
        println!("{label}: done");
    }
    println!("{}", ab.to_markdown());

    let mut out = f6a.to_csv();
    out.push_str(&f6b.to_markdown());
    out.push_str(&ab.to_markdown());
    let path = save_report("component_breakdown.md", &out)?;
    println!("saved {path:?}");
    Ok(())
}

//! Bench: compute-graph extraction — the paper's `getComputeGraph`, its
//! dominant per-batch component (Figure 6b) — across batch sizes, hop
//! counts (Figure 2 shape), and partition counts. Reports edge-visit
//! throughput, the §Perf L3 target metric.

use kgscale::config::{ExperimentConfig, PartitionConfig, PartitionStrategy};
use kgscale::graph::generator;
use kgscale::partition;
use kgscale::sampler::compute_graph::{avg_closure_size, ComputeGraphBuilder};
use kgscale::sampler::{PartContext, TrainTriple};
use kgscale::util::bench::bench;

fn main() {
    let cfg = ExperimentConfig::from_file("configs/citemini.toml")
        .unwrap_or_else(|_| ExperimentConfig::tiny());
    let g = generator::generate(&cfg.dataset);
    let mk_ctx = |p: usize| -> PartContext {
        let pcfg = PartitionConfig {
            strategy: PartitionStrategy::Hdrf,
            num_partitions: p,
            ..Default::default()
        };
        let parts = partition::partition_graph(&g, &pcfg, 42);
        PartContext::new(&parts[0])
    };

    println!("== compute-graph bench: {} entities, {} edges ==", g.num_entities, g.train.len());
    for p in [1usize, 4, 8] {
        let ctx = mk_ctx(p);
        let mut builder = ComputeGraphBuilder::new(&ctx);
        for batch_pos in [256usize, 1024] {
            let take = batch_pos.min(ctx.core_edges.len());
            let batch: Vec<TrainTriple> = ctx.core_edges[..take]
                .iter()
                .map(|e| TrainTriple { s: e.s, r: e.r, t: e.t, label: 1.0 })
                .collect();
            let cg = builder.build(&ctx, &batch, 2, g.num_relations);
            let edges = cg.num_edges();
            let r = bench(
                &format!("getComputeGraph/P={p}/batch={take}/2-hop"),
                0.5,
                || {
                    std::hint::black_box(builder.build(&ctx, &batch, 2, g.num_relations));
                },
            );
            println!(
                "    -> cg: {} nodes, {} msg edges; {:.1} M edge-visits/s",
                cg.num_nodes(),
                edges,
                edges as f64 / r.mean_secs / 1e6
            );
        }
    }

    println!("\n== Figure 2 shape: avg closure size vs hops (full graph) ==");
    let ctx = mk_ctx(1);
    for hops in 1..=3 {
        let avg = avg_closure_size(&ctx, hops, 100, 7);
        println!("hops={hops}: avg {avg:.1} vertices per embedding");
    }
}

//! Bench: epoch throughput on the pipelined host data path.
//!
//! Part A (always runs): epoch *planning* — per-worker negative
//! sampling + batch building — sequentially vs fanned out over a
//! [`HostPool`], the same fan-out `Trainer::train_epoch` uses.
//! Part B (needs `make artifacts`): full `train_epoch` wall time,
//! sequential (`host_threads = 0`) vs pipelined prep, with the
//! prefetch-stall and overlap-efficiency metrics the trainer reports.
//!
//! Writes a machine-readable summary to `BENCH_epoch.json` (path
//! overridable via the `BENCH_EPOCH_JSON` env var) for
//! `scripts/run_benches.sh`.

use kgscale::config::{ExperimentConfig, PartitionConfig, PartitionStrategy};
use kgscale::graph::generator;
use kgscale::model::Manifest;
use kgscale::partition;
use kgscale::runtime::Runtime;
use kgscale::sampler::batch::EpochBatches;
use kgscale::sampler::negative::{NegativeSampler, Scope};
use kgscale::sampler::PartContext;
use kgscale::train::{worker_epoch_seed, HostPool, Trainer};
use kgscale::util::bench::{bench, BenchResult};
use kgscale::util::json::Json;
use kgscale::util::rng::Rng;
use std::path::Path;
use std::sync::{mpsc, Arc};

const NEGATIVES: usize = 2;
const BATCH_EDGES: usize = 64;

/// One worker's epoch plan (the exact work `Trainer::plan_epoch` does
/// per wid, minus the remote-fetch accounting).
fn plan_worker(ctx: &PartContext, sampler: &NegativeSampler, wid: usize) -> usize {
    let mut rng = Rng::seeded(worker_epoch_seed(7, 0, wid));
    let (negs, _) = sampler.sample_epoch(ctx, NEGATIVES, &mut rng);
    let ep = EpochBatches::build(ctx, negs, BATCH_EDGES, &mut rng);
    ep.num_batches()
}

fn json_result(r: &BenchResult) -> Json {
    Json::obj(vec![
        ("name", Json::Str(r.name.clone())),
        ("mean_secs", Json::Num(r.mean_secs)),
        ("std_secs", Json::Num(r.std_secs)),
        ("min_secs", Json::Num(r.min_secs)),
        ("iters", Json::Num(r.iters as f64)),
    ])
}

/// Part A: plan-epoch fan-out, no XLA artifacts needed.
fn bench_planning(results: &mut Vec<Json>) {
    let cfg = ExperimentConfig::tiny();
    let g = generator::generate(&cfg.dataset);
    let pcfg = PartitionConfig {
        strategy: PartitionStrategy::Hdrf,
        num_partitions: 4,
        ..Default::default()
    };
    let parts = partition::partition_graph(&g, &pcfg, cfg.train.seed);
    let workers: Vec<Arc<(PartContext, NegativeSampler)>> = parts
        .iter()
        .map(|part| {
            let ctx = PartContext::new(part);
            let sampler = NegativeSampler::new(&ctx, Scope::LocalCore, g.num_entities);
            Arc::new((ctx, sampler))
        })
        .collect();

    println!("== epoch-plan fan-out (tiny, {} partitions) ==", workers.len());
    let seq = bench("plan-epoch/sequential", 0.5, || {
        let total: usize =
            workers.iter().enumerate().map(|(wid, w)| plan_worker(&w.0, &w.1, wid)).sum();
        std::hint::black_box(total);
    });
    results.push(json_result(&seq));
    for threads in [2usize, 4] {
        let pool = HostPool::new(threads);
        let r = bench(&format!("plan-epoch/pool-{threads}"), 0.5, || {
            let (tx, rx) = mpsc::channel();
            for (wid, w) in workers.iter().enumerate() {
                let w = Arc::clone(w);
                let tx = tx.clone();
                pool.submit(move || {
                    tx.send(plan_worker(&w.0, &w.1, wid)).expect("collector alive");
                });
            }
            drop(tx);
            let total: usize = rx.iter().sum();
            std::hint::black_box(total);
        });
        results.push(json_result(&r));
    }
}

/// Part B: full train_epoch, sequential vs pipelined host prep.
fn bench_train_epoch(results: &mut Vec<Json>) {
    let dir = Path::new("artifacts/tiny");
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP train_epoch bench: run `make artifacts` first");
        return;
    }
    let manifest = Manifest::load(dir).unwrap();
    let runtime = Runtime::new(dir).unwrap();
    let base = ExperimentConfig::tiny();
    let g = generator::generate(&base.dataset);

    println!("== train_epoch: sequential vs pipelined host prep ==");
    println!(
        "{:<22} {:>12} {:>12} {:>12} {:>10}",
        "config", "wall epoch", "virt epoch", "stall", "overlap"
    );
    for threads in [0usize, 2] {
        let mut c = base.clone();
        c.train.batch_edges = BATCH_EDGES;
        c.train.num_trainers = 2;
        c.train.host_threads = threads;
        c.train.prefetch_depth = 2;
        let mut t = Trainer::new(c, &g, &runtime, manifest.clone()).unwrap();
        // Warm epoch (JIT load, allocator churn) before measuring.
        t.train_epoch().unwrap();
        let (mut wall, mut virt, mut stall, mut overlap) = (0.0, 0.0, 0.0, 0.0);
        let epochs = 3;
        for _ in 0..epochs {
            let r = t.train_epoch().unwrap();
            wall += r.wall_secs;
            virt += r.virtual_secs;
            stall += r.prefetch_stall_secs;
            overlap += r.overlap_efficiency;
        }
        let n = epochs as f64;
        let name = if threads == 0 {
            "train-epoch/sequential".to_string()
        } else {
            format!("train-epoch/pipelined-{threads}")
        };
        println!(
            "{:<22} {:>11.4}s {:>11.4}s {:>11.4}s {:>10.2}",
            name,
            wall / n,
            virt / n,
            stall / n,
            overlap / n
        );
        results.push(Json::obj(vec![
            ("name", Json::Str(name)),
            ("host_threads", Json::Num(threads as f64)),
            ("wall_epoch_secs", Json::Num(wall / n)),
            ("virtual_epoch_secs", Json::Num(virt / n)),
            ("prefetch_stall_secs", Json::Num(stall / n)),
            ("overlap_efficiency", Json::Num(overlap / n)),
        ]));
    }
}

fn main() {
    let mut results = Vec::new();
    bench_planning(&mut results);
    bench_train_epoch(&mut results);
    let out = Json::obj(vec![
        ("bench", Json::Str("epoch".to_string())),
        ("tier", Json::Str("tiny".to_string())),
        ("results", Json::Arr(results)),
    ]);
    let path =
        std::env::var("BENCH_EPOCH_JSON").unwrap_or_else(|_| "BENCH_epoch.json".to_string());
    std::fs::write(&path, out.to_string_pretty()).expect("write bench json");
    println!("wrote {path}");
}

//! Bench: crash-consistent checkpointing and fault recovery.
//!
//! Part A (always runs): checkpoint v3 save/load round-trip cost at
//! embedding-table sizes of 100k and 1M parameters — the atomic
//! tmp+rename write with the FNV-1a footer vs the checksum-verifying
//! read. This is the per-boundary cost `train.checkpoint_every_epochs`
//! charges and the read half of every crash recovery.
//! Part B (needs `make artifacts`): full `train_epoch` wall/virtual
//! time, fault-free vs an aggressive seeded fault plan with recovery,
//! reporting the recovery/checkpoint accounting the trainer emits.
//!
//! Writes a machine-readable summary to `BENCH_recovery.json` (path
//! overridable via the `BENCH_RECOVERY_JSON` env var) for
//! `scripts/run_benches.sh`.

use kgscale::config::{ExperimentConfig, GradMode, GradSync};
use kgscale::graph::generator;
use kgscale::model::Manifest;
use kgscale::runtime::Runtime;
use kgscale::train::{checkpoint, Trainer};
use kgscale::util::bench::{bench, BenchResult};
use kgscale::util::json::Json;
use kgscale::util::rng::Rng;
use std::path::Path;

fn json_result(r: &BenchResult) -> Json {
    Json::obj(vec![
        ("name", Json::Str(r.name.clone())),
        ("mean_secs", Json::Num(r.mean_secs)),
        ("std_secs", Json::Num(r.std_secs)),
        ("min_secs", Json::Num(r.min_secs)),
        ("iters", Json::Num(r.iters as f64)),
    ])
}

fn scratch_dir(tag: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir()
        .join(format!("kgscale-bench-recovery-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).expect("create bench scratch dir");
    d
}

/// Part A: checkpoint save/load round trips, no XLA artifacts needed.
fn bench_checkpoint_io(results: &mut Vec<Json>) {
    println!("== checkpoint v3 save/load (atomic rename + FNV-1a footer) ==");
    let dir = scratch_dir("io");
    for n in [100_000usize, 1_000_000] {
        let mut rng = Rng::seeded(0xC4EC);
        let params: Vec<f32> = (0..n).map(|_| rng.next_f32() - 0.5).collect();
        let m = vec![0.01f32; n];
        let v = vec![0.002f32; n];
        let label = if n >= 1_000_000 { "1M" } else { "100k" };
        let path = dir.join(format!("bench-{label}.ckpt"));

        let save = bench(&format!("checkpoint-save/{label}"), 0.5, || {
            checkpoint::save(&path, &params, &m, &v, 42, GradMode::Sparse, 7).unwrap();
        });
        results.push(json_result(&save));

        let load = bench(&format!("checkpoint-load/{label}"), 0.5, || {
            let ck = checkpoint::load(&path).unwrap();
            std::hint::black_box(ck.params.len());
        });
        results.push(json_result(&load));
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// Part B: train_epoch under a fault plan vs fault-free, with the
/// recovery accounting the trainer reports.
fn bench_faulted_epochs(results: &mut Vec<Json>) {
    let dir = Path::new("artifacts/tiny");
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP faulted train_epoch bench: run `make artifacts` first");
        return;
    }
    let manifest = Manifest::load(dir).unwrap();
    let runtime = Runtime::new(dir).unwrap();
    let base = ExperimentConfig::tiny();
    let g = generator::generate(&base.dataset);

    println!("== train_epoch: fault-free vs crash/straggler plan with recovery ==");
    println!(
        "{:<26} {:>12} {:>12} {:>10} {:>12} {:>12}",
        "config", "wall epoch", "virt epoch", "crashes", "recovery", "ckpt write"
    );
    for faulted in [false, true] {
        let ckpt_dir = scratch_dir(if faulted { "faulted" } else { "clean" });
        let mut c = base.clone();
        c.train.batch_edges = 64;
        c.train.num_trainers = 2;
        c.train.grad_sync = GradSync::Ring;
        if faulted {
            c.train.checkpoint_every_epochs = 1;
            c.train.checkpoint_dir = ckpt_dir.to_string_lossy().into_owned();
            c.faults.enabled = true;
            c.faults.crash_rate = 0.1;
            c.faults.straggler_rate = 0.5;
            c.faults.link_degrade_rate = 0.5;
        }
        let mut t = Trainer::new(c, &g, &runtime, manifest.clone()).unwrap();
        // Warm epoch (JIT load, allocator churn) before measuring.
        t.train_epoch().unwrap();
        let (mut wall, mut virt, mut recov, mut ckpt) = (0.0, 0.0, 0.0, 0.0);
        let mut crashes = 0usize;
        let epochs = 3;
        for _ in 0..epochs {
            let r = t.train_epoch().unwrap();
            wall += r.wall_secs;
            virt += r.virtual_secs;
            recov += r.recovery_secs;
            ckpt += r.checkpoint_write_secs;
            crashes += r.fault_recoveries;
        }
        let n = epochs as f64;
        let name = if faulted { "train-epoch/faulted" } else { "train-epoch/fault-free" };
        println!(
            "{:<26} {:>11.4}s {:>11.4}s {:>10} {:>11.4}s {:>11.4}s",
            name,
            wall / n,
            virt / n,
            crashes,
            recov / n,
            ckpt / n
        );
        results.push(Json::obj(vec![
            ("name", Json::Str(name.to_string())),
            ("wall_epoch_secs", Json::Num(wall / n)),
            ("virtual_epoch_secs", Json::Num(virt / n)),
            ("crashes", Json::Num(crashes as f64)),
            ("recovery_secs_per_epoch", Json::Num(recov / n)),
            ("checkpoint_write_secs_per_epoch", Json::Num(ckpt / n)),
        ]));
        let _ = std::fs::remove_dir_all(&ckpt_dir);
    }
}

fn main() {
    let mut results = Vec::new();
    bench_checkpoint_io(&mut results);
    bench_faulted_epochs(&mut results);
    let out = Json::obj(vec![
        ("bench", Json::Str("recovery".to_string())),
        ("tier", Json::Str("tiny".to_string())),
        ("results", Json::Arr(results)),
    ]);
    let path =
        std::env::var("BENCH_RECOVERY_JSON").unwrap_or_else(|_| "BENCH_recovery.json".to_string());
    std::fs::write(&path, out.to_string_pretty()).expect("write bench json");
    println!("wrote {path}");
}

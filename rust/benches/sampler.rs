//! Bench: negative sampling throughput — local constraint-based (paper)
//! vs partition-wide vs global scope (§3.3.1), plus epoch batching.

use kgscale::config::{ExperimentConfig, PartitionConfig, PartitionStrategy};
use kgscale::graph::generator;
use kgscale::partition;
use kgscale::sampler::batch::EpochBatches;
use kgscale::sampler::negative::{NegativeSampler, Scope};
use kgscale::sampler::PartContext;
use kgscale::util::bench::bench;
use kgscale::util::rng::Rng;

fn main() {
    let cfg = ExperimentConfig::from_file("configs/fbmini.toml")
        .unwrap_or_else(|_| ExperimentConfig::tiny());
    let g = generator::generate(&cfg.dataset);
    let pcfg = PartitionConfig {
        strategy: PartitionStrategy::Hdrf,
        num_partitions: 4,
        ..Default::default()
    };
    let parts = partition::partition_graph(&g, &pcfg, 42);
    let ctx = PartContext::new(&parts[0]);
    println!(
        "== sampler bench: partition 0 has {} core edges, {} core vertices ==",
        ctx.core_edges.len(),
        ctx.core_vertices.len()
    );

    for (label, scope) in [
        ("local-core (paper)", Scope::LocalCore),
        ("partition-wide", Scope::PartitionWide),
        ("global (ablation)", Scope::Global),
    ] {
        let sampler = NegativeSampler::new(&ctx, scope, g.num_entities);
        let r = bench(&format!("negatives/{label}/1-per-pos"), 0.5, || {
            let mut rng = Rng::seeded(7);
            std::hint::black_box(sampler.sample_epoch(&ctx, 1, &mut rng));
        });
        let per_sample = r.mean_secs / ctx.core_edges.len() as f64;
        println!("    -> {:.1} ns/negative", per_sample * 1e9);
    }

    let sampler = NegativeSampler::new(&ctx, Scope::LocalCore, g.num_entities);
    let mut rng = Rng::seeded(7);
    let (negs, _) = sampler.sample_epoch(&ctx, 1, &mut rng);
    bench("epoch-batching/full-batch", 0.5, || {
        let mut rng = Rng::seeded(9);
        std::hint::black_box(EpochBatches::build(&ctx, negs.clone(), 0, &mut rng));
    });
    bench("epoch-batching/minibatch-1024", 0.5, || {
        let mut rng = Rng::seeded(9);
        std::hint::black_box(EpochBatches::build(&ctx, negs.clone(), 1024, &mut rng));
    });
}

//! Bench: ring AllReduce vs parameter-server aggregation (paper §2.2's
//! motivation for choosing AllReduce) — real in-memory reduction cost
//! across worker counts and message sizes, plus the α-β model's predicted
//! wire times for the paper's 40GbE cluster.

use kgscale::config::ExperimentConfig;
use kgscale::train::allreduce::{param_server_sum, ring_allreduce_sum};
use kgscale::train::netsim::NetworkModel;
use kgscale::util::bench::bench;
use kgscale::util::rng::Rng;

fn buffers(p: usize, n: usize) -> Vec<Vec<f32>> {
    let mut rng = Rng::seeded(1);
    (0..p).map(|_| (0..n).map(|_| rng.uniform_f32(-1.0, 1.0)).collect()).collect()
}

fn main() {
    println!("== allreduce bench (in-memory reduction) ==");
    for p in [2usize, 4, 8] {
        for n in [65_536usize, 1_048_576] {
            let base = buffers(p, n);
            bench(&format!("ring/P={p}/{}k-f32", n / 1024), 0.4, || {
                let mut b = base.clone();
                ring_allreduce_sum(&mut b);
                std::hint::black_box(b);
            });
            bench(&format!("param-server/P={p}/{}k-f32", n / 1024), 0.4, || {
                let mut b = base.clone();
                param_server_sum(&mut b);
                std::hint::black_box(b);
            });
        }
    }

    println!("\n== α-β model: predicted sync time on the paper's 40GbE cluster ==");
    let net = NetworkModel::new(&ExperimentConfig::tiny().network);
    println!("{:<10} {:>14} {:>14}", "P", "ring", "param-server");
    for p in [2usize, 4, 8, 16] {
        let bytes = 4 * 1_048_576; // 1M f32 gradients = 4 MB
        println!(
            "{:<10} {:>12.3}ms {:>12.3}ms",
            p,
            net.ring_allreduce_secs(bytes, p) * 1e3,
            net.param_server_secs(bytes, p) * 1e3
        );
    }
}

//! Bench: end-to-end epoch time vs trainer count (Table 3/4's timing
//! columns, Figure 6a) on the tiny tier — small enough for `make bench`
//! to finish quickly; the -mini tier numbers live in EXPERIMENTS.md via
//! the examples. Requires `make artifacts`.

use kgscale::config::ExperimentConfig;
use kgscale::graph::generator;
use kgscale::model::Manifest;
use kgscale::runtime::Runtime;
use kgscale::train::Trainer;
use std::path::Path;

fn main() {
    let dir = Path::new("artifacts/tiny");
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP end_to_end bench: run `make artifacts` first");
        return;
    }
    let cfg = ExperimentConfig::tiny();
    let g = generator::generate(&cfg.dataset);
    let manifest = Manifest::load(dir).unwrap();
    let runtime = Runtime::new(dir).unwrap();

    println!("== end-to-end epoch bench (tiny, full batch) ==");
    println!(
        "{:<10} {:>16} {:>16} {:>10} {:>12}",
        "trainers", "virt epoch", "wall epoch", "speedup", "loss@3ep"
    );
    let mut base = 0.0;
    for p in [1usize, 2, 4, 8] {
        let mut c = cfg.clone();
        c.train.num_trainers = p;
        let mut t = Trainer::new(c, &g, &runtime, manifest.clone()).unwrap();
        // Warm epoch (not timed), then 3 measured epochs.
        t.train_epoch().unwrap();
        let mut virt = 0.0;
        let mut wall = 0.0;
        let mut loss = 0.0;
        for _ in 0..3 {
            let r = t.train_epoch().unwrap();
            virt += r.virtual_secs;
            wall += r.wall_secs;
            loss = r.mean_loss;
        }
        virt /= 3.0;
        wall /= 3.0;
        if p == 1 {
            base = virt;
        }
        println!(
            "{:<10} {:>14.4}s {:>14.4}s {:>9.2}x {:>12.4}",
            p,
            virt,
            wall,
            base / virt,
            loss
        );
    }
}

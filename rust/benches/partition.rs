//! Bench: partitioning strategies (Table 2 / Table 5 substrate).
//! Measures HDRF / DBH / Greedy-VP / Random assignment and 2-hop
//! neighborhood expansion on the fbmini-scale graph, and prints the
//! partition-quality stats the paper's tables report.

use kgscale::config::{ExperimentConfig, PartitionConfig, PartitionStrategy};
use kgscale::graph::generator;
use kgscale::partition::{self, stats as pstats};
use kgscale::util::bench::bench;

fn main() {
    let cfg = ExperimentConfig::from_file("configs/fbmini.toml")
        .unwrap_or_else(|_| ExperimentConfig::tiny());
    let g = generator::generate(&cfg.dataset);
    println!(
        "== partition bench: {} entities, {} train edges ==",
        g.num_entities,
        g.train.len()
    );

    for strategy in [
        PartitionStrategy::Hdrf,
        PartitionStrategy::Dbh,
        PartitionStrategy::MetisLike,
        PartitionStrategy::Random,
    ] {
        let pcfg =
            PartitionConfig { strategy, num_partitions: 4, hops: 2, hdrf_lambda: 1.0 };
        bench(&format!("assign/{}/P=4", strategy.name()), 0.6, || {
            std::hint::black_box(partition::assign_edges(&g, &pcfg, 42));
        });
        let assignment = partition::assign_edges(&g, &pcfg, 42);
        bench(&format!("expand-2hop/{}/P=4", strategy.name()), 0.6, || {
            std::hint::black_box(partition::expansion::expand(&g, &assignment, 2));
        });
        let parts = partition::expansion::expand(&g, &assignment, 2);
        let s = pstats::compute(&parts, g.num_entities);
        println!(
            "    -> core {} | total {} | RF {:.2} | balance {:.2}",
            s.core_cell(),
            s.total_cell(),
            s.replication_factor,
            s.balance_ratio
        );
    }

    // Table 2 shape: RF vs P for HDRF.
    for p in [2usize, 4, 8] {
        let pcfg = PartitionConfig {
            strategy: PartitionStrategy::Hdrf,
            num_partitions: p,
            hops: 2,
            hdrf_lambda: 1.0,
        };
        let parts = partition::partition_graph(&g, &pcfg, 42);
        let s = pstats::compute(&parts, g.num_entities);
        println!(
            "table2: P={p} core {} total {} RF {:.2}",
            s.core_cell(),
            s.total_cell(),
            s.replication_factor
        );
    }
}

//! Bench: partitioning strategies (Table 2 / Table 5 substrate) and the
//! parallel build pipeline.
//!
//! Measures HDRF / DBH / Greedy-VP / Random assignment and 2-hop
//! neighborhood expansion on the fbmini-scale graph, and prints the
//! partition-quality stats the paper's tables report. Then benches the
//! tentpole paths: sequential vs multi-threaded expansion (bit-identity
//! asserted outside the timing loop) and cold vs warm on-disk partition
//! cache.
//!
//! Writes a machine-readable summary to `BENCH_partition.json` (path
//! overridable via the `BENCH_PARTITION_JSON` env var) for
//! `scripts/run_benches.sh`.

use kgscale::config::{ExperimentConfig, PartitionConfig, PartitionStrategy};
use kgscale::graph::{generator, Csr, KnowledgeGraph};
use kgscale::partition::{self, stats as pstats};
use kgscale::util::bench::{bench, BenchResult};
use kgscale::util::json::Json;

fn json_result(r: &BenchResult) -> Json {
    Json::obj(vec![
        ("name", Json::Str(r.name.clone())),
        ("mean_secs", Json::Num(r.mean_secs)),
        ("std_secs", Json::Num(r.std_secs)),
        ("min_secs", Json::Num(r.min_secs)),
        ("iters", Json::Num(r.iters as f64)),
    ])
}

fn bench_strategies(g: &KnowledgeGraph, results: &mut Vec<Json>) {
    for strategy in [
        PartitionStrategy::Hdrf,
        PartitionStrategy::Dbh,
        PartitionStrategy::MetisLike,
        PartitionStrategy::Random,
    ] {
        let pcfg = PartitionConfig { strategy, num_partitions: 4, ..Default::default() };
        let r = bench(&format!("assign/{}/P=4", strategy.name()), 0.6, || {
            std::hint::black_box(partition::assign_edges(g, &pcfg, 42));
        });
        results.push(json_result(&r));
        let assignment = partition::assign_edges(g, &pcfg, 42);
        let r = bench(&format!("expand-2hop/{}/P=4", strategy.name()), 0.6, || {
            std::hint::black_box(partition::expansion::expand(g, &assignment, 2));
        });
        results.push(json_result(&r));
        let parts = partition::expansion::expand(g, &assignment, 2);
        let s = pstats::compute(&parts, g.num_entities);
        println!(
            "    -> core {} | total {} | RF {:.2} | balance {:.2}",
            s.core_cell(),
            s.total_cell(),
            s.replication_factor,
            s.balance_ratio
        );
    }
}

/// Tentpole A: sequential (`build_threads = 0`) vs threaded expansion
/// over a shared CSR, P=8 so the fan-out has work to distribute.
fn bench_threaded_expansion(g: &KnowledgeGraph, results: &mut Vec<Json>) {
    let pcfg = PartitionConfig { num_partitions: 8, ..Default::default() };
    let csr = Csr::build(g.num_entities, &g.train);
    let assignment = partition::assign_edges_with(g, &csr, &pcfg, 42);
    let want = partition::expansion::expand_with(g, &csr, &assignment, 2, 0);
    let mut seq_mean = 0.0;
    for threads in [0usize, 2, 4] {
        // Correctness outside the timing loop: any thread count must be
        // bit-identical to the sequential reference.
        let got = partition::expansion::expand_with(g, &csr, &assignment, 2, threads);
        assert_eq!(got, want, "threaded expansion diverged at {threads} threads");
        let label = if threads == 0 {
            "expand/P=8/sequential".to_string()
        } else {
            format!("expand/P=8/threads-{threads}")
        };
        let r = bench(&label, 0.6, || {
            std::hint::black_box(partition::expansion::expand_with(
                g,
                &csr,
                &assignment,
                2,
                threads,
            ));
        });
        if threads == 0 {
            seq_mean = r.mean_secs;
        } else {
            println!("    -> {:.2}x vs sequential", seq_mean / r.mean_secs.max(1e-12));
        }
        results.push(json_result(&r));
    }
}

/// Tentpole B: full `build_partitions` cold (rebuild + cache write) vs
/// warm (cache load). The warm path must report a hit every iteration.
fn bench_cache(g: &KnowledgeGraph, results: &mut Vec<Json>) {
    let dir = std::env::temp_dir().join(format!("kgscale-bench-pcache-{}", std::process::id()));
    let pcfg = PartitionConfig {
        num_partitions: 8,
        build_threads: 2,
        cache_dir: dir.to_string_lossy().into_owned(),
        ..Default::default()
    };
    let r = bench("build/P=8/cold-cache", 0.6, || {
        // Remove the entry inside the timing loop: every iteration pays
        // assignment + expansion + serialization, like a first run.
        let _ = std::fs::remove_dir_all(&dir);
        let (parts, stats) = partition::build_partitions(g, &pcfg, 42);
        assert!(!stats.cache_hit);
        std::hint::black_box(parts);
    });
    results.push(json_result(&r));
    let cold_mean = r.mean_secs;

    let (want, _) = partition::build_partitions(g, &pcfg, 42); // prime the cache
    let r = bench("build/P=8/warm-cache", 0.6, || {
        let (parts, stats) = partition::build_partitions(g, &pcfg, 42);
        assert!(stats.cache_hit, "warm build must load from cache");
        std::hint::black_box(parts);
    });
    println!("    -> warm {:.2}x vs cold", cold_mean / r.mean_secs.max(1e-12));
    results.push(json_result(&r));

    // Loaded output is bit-identical to a rebuilt one.
    let (warm, _) = partition::build_partitions(g, &pcfg, 42);
    assert_eq!(warm, want, "cache round-trip changed the partitions");
    let _ = std::fs::remove_dir_all(&dir);
}

fn main() {
    let cfg = ExperimentConfig::from_file("configs/fbmini.toml")
        .unwrap_or_else(|_| ExperimentConfig::tiny());
    let g = generator::generate(&cfg.dataset);
    println!(
        "== partition bench: {} entities, {} train edges ==",
        g.num_entities,
        g.train.len()
    );

    let mut results = Vec::new();
    bench_strategies(&g, &mut results);
    bench_threaded_expansion(&g, &mut results);
    bench_cache(&g, &mut results);

    // Table 2 shape: RF vs P for HDRF.
    for p in [2usize, 4, 8] {
        let pcfg = PartitionConfig { num_partitions: p, ..Default::default() };
        let parts = partition::partition_graph(&g, &pcfg, 42);
        let s = pstats::compute(&parts, g.num_entities);
        println!(
            "table2: P={p} core {} total {} RF {:.2}",
            s.core_cell(),
            s.total_cell(),
            s.replication_factor
        );
    }

    let out = Json::obj(vec![
        ("bench", Json::Str("partition".to_string())),
        ("tier", Json::Str(cfg.name.clone())),
        ("results", Json::Arr(results)),
    ]);
    let path = std::env::var("BENCH_PARTITION_JSON")
        .unwrap_or_else(|_| "BENCH_partition.json".to_string());
    std::fs::write(&path, out.to_string_pretty()).expect("write bench json");
    println!("wrote {path}");
}

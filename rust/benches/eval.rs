//! Bench: filtered-rank throughput on the overlapped eval path.
//!
//! Part A (always runs): rank a synthetic score stream sequentially vs
//! through [`EvalPipeline`] at tiny/small scales — the same coordinator
//! fill + pool rank fan-out `Evaluator` uses, minus XLA. Verifies the
//! two paths are bit-identical and that per-chunk score readback reuses
//! the rotating slot buffers (zero per-chunk heap allocation: at most
//! `prefetch_depth` distinct (ptr, capacity) pairs ever observed).
//! Part B (needs `make artifacts`): full `Evaluator::evaluate` wall
//! time, sequential (`eval.host_threads = 0`) vs overlapped, with the
//! rank-stall and overlap-efficiency metrics the trainer reports.
//!
//! Writes a machine-readable summary to `BENCH_eval.json` (path
//! overridable via the `BENCH_EVAL_JSON` env var) for
//! `scripts/run_benches.sh`.

use kgscale::config::{EvalConfig, ExperimentConfig};
use kgscale::eval::{build_queries, Evaluator, FilterIndex, Query, RankMetrics};
use kgscale::eval::{filtered_rank_sorting, EvalPipeline};
use kgscale::graph::generator;
use kgscale::model::Manifest;
use kgscale::runtime::Runtime;
use kgscale::util::bench::{bench, BenchResult};
use kgscale::util::json::Json;
use kgscale::util::pool::HostPool;
use std::collections::HashSet;
use std::path::Path;
use std::sync::Arc;

const Q_PAD: usize = 128;
const DEPTH: usize = 2;

/// Deterministic synthetic score (coarse-quantized: plenty of ties).
fn synth_score(qi: usize, c: usize) -> f32 {
    ((qi.wrapping_mul(31) ^ c.wrapping_mul(17)) % 97) as f32 * 0.5 - 10.0
}

/// Write one chunk of synthetic scores into `buf` (n_pad == n_ent here).
fn fill_chunk(buf: &mut Vec<f32>, start: usize, len: usize, n_ent: usize) {
    buf.resize(Q_PAD * n_ent, 0.0);
    for i in 0..len {
        let row = &mut buf[i * n_ent..(i + 1) * n_ent];
        for (c, v) in row.iter_mut().enumerate() {
            *v = synth_score(start + i, c);
        }
    }
}

/// Sequential reference: fill + rank every chunk on this thread.
fn rank_sequential(
    queries: &[Query],
    filter: &FilterIndex,
    n_ent: usize,
    scores: &mut Vec<f32>,
    scratch: &mut Vec<u32>,
) -> RankMetrics {
    let mut m = RankMetrics::default();
    let mut start = 0;
    while start < queries.len() {
        let len = Q_PAD.min(queries.len() - start);
        fill_chunk(scores, start, len, n_ent);
        for (i, q) in queries[start..start + len].iter().enumerate() {
            let row = &scores[i * n_ent..(i + 1) * n_ent];
            let known = if q.tail_dir {
                filter.known_tails(q.anchor, q.r)
            } else {
                filter.known_heads(q.anchor, q.r)
            };
            m.fold(filtered_rank_sorting(row, q.truth, known, scratch));
        }
        start += len;
    }
    m.finalize();
    m
}

/// Overlapped path: coordinator fills chunk s+1 while the pool ranks
/// chunk s. Returns the metrics plus every (ptr, capacity) the slot
/// buffers ever showed — the zero-per-chunk-allocation evidence.
fn rank_overlapped(
    pool: &HostPool,
    queries: &Arc<Vec<Query>>,
    filter: &FilterIndex,
    n_ent: usize,
) -> (RankMetrics, HashSet<(usize, usize)>) {
    let mut pipe = EvalPipeline::new(
        pool,
        Arc::clone(queries),
        filter.clone(),
        Q_PAD,
        n_ent,
        n_ent,
        DEPTH,
    );
    let mut m = RankMetrics::default();
    let mut bufs = HashSet::new();
    let mut start = 0;
    while start < queries.len() {
        let len = Q_PAD.min(queries.len() - start);
        pipe.submit_chunk(start, len, &mut m, |buf| {
            fill_chunk(buf, start, len, n_ent);
            bufs.insert((buf.as_ptr() as usize, buf.capacity()));
            Ok(())
        })
        .expect("synthetic chunk");
        start += len;
    }
    pipe.finish(&mut m);
    m.finalize();
    (m, bufs)
}

fn json_result(r: &BenchResult, queries: usize) -> Json {
    Json::obj(vec![
        ("name", Json::Str(r.name.clone())),
        ("mean_secs", Json::Num(r.mean_secs)),
        ("std_secs", Json::Num(r.std_secs)),
        ("min_secs", Json::Num(r.min_secs)),
        ("iters", Json::Num(r.iters as f64)),
        ("queries_per_sec", Json::Num(queries as f64 / r.mean_secs.max(1e-12))),
    ])
}

/// Part A: synthetic-score ranking, no XLA artifacts needed.
fn bench_rank_path(results: &mut Vec<Json>) {
    let tiny = ExperimentConfig::tiny().dataset;
    let mut small = tiny.clone();
    small.name = "small".into();
    small.entities = 1500;
    small.train_edges = 6000;
    small.valid_edges = 300;
    small.test_edges = 600;

    for dcfg in [tiny, small] {
        let g = generator::generate(&dcfg);
        let filter = FilterIndex::build(&g).unwrap();
        let queries = Arc::new(build_queries(&g.test));
        let n_ent = g.num_entities;
        println!(
            "== filtered-rank path ({}, {} queries x {} entities) ==",
            dcfg.name,
            queries.len(),
            n_ent
        );

        let mut scores = Vec::new();
        let mut scratch = Vec::new();
        let want = rank_sequential(&queries, &filter, n_ent, &mut scores, &mut scratch);
        let seq = bench(&format!("rank/{}/sequential", dcfg.name), 0.5, || {
            let m = rank_sequential(&queries, &filter, n_ent, &mut scores, &mut scratch);
            std::hint::black_box(m.mrr);
        });
        println!(
            "{:<26} {:>10.2} q/s",
            seq.name,
            queries.len() as f64 / seq.mean_secs.max(1e-12)
        );
        results.push(json_result(&seq, queries.len()));

        for threads in [2usize, 4] {
            let pool = HostPool::new(threads);
            // Correctness pass outside the timing loop: bit-identical
            // metrics, and slot buffers never reallocate per chunk.
            let (got, bufs) = rank_overlapped(&pool, &queries, &filter, n_ent);
            assert_eq!(
                got.mrr.to_bits(),
                want.mrr.to_bits(),
                "overlapped ranking must be bit-identical to sequential"
            );
            assert_eq!(got.hits10.to_bits(), want.hits10.to_bits());
            assert_eq!(got.num_queries, want.num_queries);
            let chunks = queries.len().div_ceil(Q_PAD);
            assert!(
                bufs.len() <= DEPTH,
                "score readback must reuse <= {DEPTH} slot buffers across {chunks} \
                 chunks, saw {} distinct (ptr, capacity) pairs",
                bufs.len()
            );
            let r = bench(&format!("rank/{}/pool-{threads}", dcfg.name), 0.5, || {
                let (m, _) = rank_overlapped(&pool, &queries, &filter, n_ent);
                std::hint::black_box(m.mrr);
            });
            println!(
                "{:<26} {:>10.2} q/s ({:.2}x vs sequential)",
                r.name,
                queries.len() as f64 / r.mean_secs.max(1e-12),
                seq.mean_secs / r.mean_secs.max(1e-12)
            );
            results.push(json_result(&r, queries.len()));
        }
        println!();
    }
}

/// Part B: full Evaluator (encode + score + rank) over real artifacts.
fn bench_evaluator(results: &mut Vec<Json>) {
    let dir = Path::new("artifacts/tiny");
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP evaluator bench: run `make artifacts` first");
        return;
    }
    let manifest = Manifest::load(dir).unwrap();
    let runtime = Runtime::new(dir).unwrap();
    let cfg = ExperimentConfig::tiny();
    let g = generator::generate(&cfg.dataset);
    let filter = FilterIndex::build(&g).unwrap();
    let params = kgscale::model::init_params(&manifest, 1);

    println!("== Evaluator: sequential vs overlapped rank pool ==");
    println!(
        "{:<24} {:>10} {:>10} {:>10} {:>10}",
        "config", "eval wall", "score", "rank stall", "overlap"
    );
    let mut ref_bits = None;
    for threads in [0usize, 2] {
        let ecfg = EvalConfig { host_threads: threads, prefetch_depth: DEPTH };
        let mut ev = Evaluator::new(&manifest, &g, &ecfg).unwrap();
        // Warm pass (artifact compile, buffer growth) before measuring;
        // also the bit-identity checkpoint between the two configs.
        let (m, _) = ev.evaluate(&runtime, &manifest, &params, &filter, &g.test).unwrap();
        match ref_bits {
            None => ref_bits = Some(m.mrr.to_bits()),
            Some(b) => assert_eq!(
                b,
                m.mrr.to_bits(),
                "overlapped Evaluator must be bit-identical to sequential"
            ),
        }
        let evals = 3;
        let (mut wall, mut score, mut stall, mut overlap) = (0.0, 0.0, 0.0, 0.0);
        for _ in 0..evals {
            let (_, s) = ev.evaluate(&runtime, &manifest, &params, &filter, &g.test).unwrap();
            wall += s.wall_secs;
            score += s.score_secs;
            stall += s.rank_stall_secs;
            overlap += s.overlap_efficiency;
        }
        let n = evals as f64;
        let name = if threads == 0 {
            "evaluate/sequential".to_string()
        } else {
            format!("evaluate/pool-{threads}")
        };
        println!(
            "{:<24} {:>9.4}s {:>9.4}s {:>9.4}s {:>10.2}",
            name,
            wall / n,
            score / n,
            stall / n,
            overlap / n
        );
        results.push(Json::obj(vec![
            ("name", Json::Str(name)),
            ("host_threads", Json::Num(threads as f64)),
            ("eval_wall_secs", Json::Num(wall / n)),
            ("score_secs", Json::Num(score / n)),
            ("rank_stall_secs", Json::Num(stall / n)),
            ("overlap_efficiency", Json::Num(overlap / n)),
        ]));
    }
}

fn main() {
    let mut results = Vec::new();
    bench_rank_path(&mut results);
    bench_evaluator(&mut results);
    let out = Json::obj(vec![
        ("bench", Json::Str("eval".to_string())),
        ("tier", Json::Str("tiny".to_string())),
        ("results", Json::Arr(results)),
    ]);
    let path =
        std::env::var("BENCH_EVAL_JSON").unwrap_or_else(|_| "BENCH_eval.json".to_string());
    std::fs::write(&path, out.to_string_pretty()).expect("write bench json");
    println!("wrote {path}");
}

//! Bench: dense vs row-sparse gradient path (ISSUE: sparse accumulation,
//! lazy Adam). Per synchronous step the trainer must (a) reset + fill its
//! gradient accumulator and (b) run one optimizer update. The dense
//! reference does both in O(param_count); the sparse path does them in
//! O(touched rows). This bench measures each stage at entity-table sizes
//! of 10k / 100k / 1M rows with a fixed batch-scale touched set, and
//! prints the resulting speedups (the acceptance bar is >= 5x for the
//! sparse path at >= 100k rows).
//!
//! Writes a machine-readable summary to `BENCH_optimizer.json` (path
//! overridable via the `BENCH_OPTIMIZER_JSON` env var) for
//! `scripts/run_benches.sh`.

use kgscale::model::EmbeddingSegment;
use kgscale::train::optimizer::Adam;
use kgscale::train::sparse::SparseGrad;
use kgscale::util::bench::{bench, BenchResult};
use kgscale::util::json::Json;
use kgscale::util::rng::Rng;

const DIM: usize = 16;
const TAIL: usize = 64;
const TOUCHED: usize = 1024;

struct Fixture {
    seg: EmbeddingSegment,
    param_count: usize,
    /// Distinct touched rows (a batch's `nodes_global` set).
    nodes: Vec<u32>,
    /// Flat gradient as read back from XLA: exact zeros off the touched rows.
    flat: Vec<f32>,
}

fn fixture(rows: usize) -> Fixture {
    let seg = EmbeddingSegment { offset: 0, rows, dim: DIM };
    let param_count = rows * DIM + TAIL;
    let mut rng = Rng::seeded(42);
    // Evenly-spaced rows are distinct by construction and spread across
    // the table like a real shuffled batch.
    let stride = (rows / TOUCHED).max(1);
    let nodes: Vec<u32> =
        (0..TOUCHED.min(rows)).map(|i| (i * stride) as u32).collect();
    let mut flat = vec![0.0f32; param_count];
    for &r in &nodes {
        let base = r as usize * DIM;
        for g in flat[base..base + DIM].iter_mut() {
            *g = rng.uniform_f32(-1.0, 1.0);
        }
    }
    for g in flat[rows * DIM..].iter_mut() {
        *g = rng.uniform_f32(-1.0, 1.0);
    }
    Fixture { seg, param_count, nodes, flat }
}

fn speedup(dense: &BenchResult, sparse: &BenchResult) -> f64 {
    dense.mean_secs / sparse.mean_secs.max(1e-12)
}

fn json_result(r: &BenchResult) -> Json {
    Json::obj(vec![
        ("name", Json::Str(r.name.clone())),
        ("mean_secs", Json::Num(r.mean_secs)),
        ("std_secs", Json::Num(r.std_secs)),
        ("min_secs", Json::Num(r.min_secs)),
        ("iters", Json::Num(r.iters as f64)),
    ])
}

fn main() {
    let mut results = Vec::new();
    println!("== gradient path bench: dense vs row-sparse ==");
    println!(
        "dim={DIM}, dense tail={TAIL}, touched rows/batch={TOUCHED} (batch-scale \
         compute graph)\n"
    );
    for rows in [10_000usize, 100_000, 1_000_000] {
        let f = fixture(rows);
        let label = format!("{}k", rows / 1000);
        println!("-- entity rows: {rows} ({} params) --", f.param_count);

        // (a) accumulate: zero the accumulator, add one worker gradient.
        let mut accum = vec![0.0f32; f.param_count];
        let d_acc = bench(&format!("accumulate/dense/{label}"), 0.3, || {
            accum.fill(0.0);
            for (a, g) in accum.iter_mut().zip(f.flat.iter()) {
                *a += g;
            }
            std::hint::black_box(&accum);
        });
        let mut sg = SparseGrad::new(Some(f.seg), f.param_count);
        let s_acc = bench(&format!("accumulate/sparse/{label}"), 0.3, || {
            sg.clear();
            sg.accumulate(&f.nodes, &f.flat);
            std::hint::black_box(&sg);
        });

        // (b) optimizer step on the averaged gradient.
        let mut params = vec![0.1f32; f.param_count];
        let mut adam = Adam::new(f.param_count, 1e-3, 0.9, 0.999, 1e-8);
        let d_step = bench(&format!("adam-step/dense/{label}"), 0.3, || {
            adam.step(&mut params, &f.flat);
            std::hint::black_box(&params);
        });
        // `sparse` mode: scatter into the all-zero dense vector, dense
        // Adam, unscatter (bit-identical path).
        accum.fill(0.0);
        let sp_mode = bench(&format!("adam-step/sparse+dense-adam/{label}"), 0.3, || {
            sg.scatter_into(&mut accum);
            adam.step(&mut params, &accum);
            sg.clear_scatter(&mut accum);
            std::hint::black_box(&params);
        });
        drop(accum);
        // `sparse_lazy` mode: lazy Adam, O(touched) end to end.
        let mut lazy = Adam::new(f.param_count, 1e-3, 0.9, 0.999, 1e-8);
        let s_step = bench(&format!("adam-step/sparse_lazy/{label}"), 0.3, || {
            lazy.step_lazy(&mut params, &sg);
            std::hint::black_box(&params);
        });

        // Full per-step cost = accumulate + step.
        let dense_total = d_acc.mean_secs + d_step.mean_secs;
        let lazy_total = s_acc.mean_secs + s_step.mean_secs;
        println!(
            "speedup accumulate {:.1}x | lazy step {:.1}x | full step (accum+step) \
             {:.1}x | sparse+dense-adam step {:.2}x",
            speedup(&d_acc, &s_acc),
            speedup(&d_step, &s_step),
            dense_total / lazy_total.max(1e-12),
            speedup(&d_step, &sp_mode),
        );
        println!();
        for r in [&d_acc, &s_acc, &d_step, &sp_mode, &s_step] {
            results.push(json_result(r));
        }
        results.push(Json::obj(vec![
            ("name", Json::Str(format!("speedup/{label}"))),
            ("accumulate", Json::Num(speedup(&d_acc, &s_acc))),
            ("lazy_step", Json::Num(speedup(&d_step, &s_step))),
            ("full_step", Json::Num(dense_total / lazy_total.max(1e-12))),
            ("sparse_dense_adam_step", Json::Num(speedup(&d_step, &sp_mode))),
        ]));
    }
    let out = Json::obj(vec![
        ("bench", Json::Str("optimizer".to_string())),
        (
            "fixture",
            Json::obj(vec![
                ("dim", Json::Num(DIM as f64)),
                ("dense_tail", Json::Num(TAIL as f64)),
                ("touched_rows", Json::Num(TOUCHED as f64)),
            ]),
        ),
        ("results", Json::Arr(results)),
    ]);
    let path = std::env::var("BENCH_OPTIMIZER_JSON")
        .unwrap_or_else(|_| "BENCH_optimizer.json".to_string());
    std::fs::write(&path, out.to_string_pretty()).expect("write bench json");
    println!("wrote {path}");
}

//! Integration + property tests over the data pipeline (no XLA needed):
//! partitioning, expansion, sampling, batching, compute graphs, and
//! AllReduce — randomized across graphs via the in-repo prop harness.

use kgscale::config::{PartitionConfig, PartitionStrategy};
use kgscale::graph::Triple;
use kgscale::partition;
use kgscale::sampler::batch::EpochBatches;
use kgscale::sampler::compute_graph::ComputeGraphBuilder;
use kgscale::sampler::negative::{NegativeSampler, Scope};
use kgscale::sampler::PartContext;
use kgscale::testing::{gen, prop_check};
use kgscale::train::allreduce::{param_server_sum, ring_allreduce_sum};
use kgscale::util::rng::Rng;
use std::collections::HashSet;

/// Core edges of every strategy are an exact disjoint cover of the train
/// set, for random graphs and partition counts.
#[test]
fn prop_partition_disjoint_cover() {
    prop_check("partition-disjoint-cover", 0xC0FFEE, 6, |rng| {
        let g = gen::small_kg(rng);
        let p = gen::partitions(rng);
        for strategy in [
            PartitionStrategy::Hdrf,
            PartitionStrategy::Dbh,
            PartitionStrategy::MetisLike,
            PartitionStrategy::Random,
        ] {
            let cfg = PartitionConfig { strategy, num_partitions: p, ..Default::default() };
            let parts = partition::partition_graph(&g, &cfg, rng.next_u64());
            let mut seen: HashSet<u64> = HashSet::new();
            let mut total = 0;
            for part in &parts {
                for e in &part.core_edges {
                    assert!(seen.insert(e.key()), "{strategy:?}: duplicate core edge");
                    total += 1;
                }
            }
            assert_eq!(total, g.train.len(), "{strategy:?}: cover incomplete");
        }
    });
}

/// Self-sufficiency: for every partition, every vertex within hops-1 of a
/// core vertex has all incident train edges present locally.
#[test]
fn prop_expansion_self_sufficiency() {
    prop_check("expansion-self-sufficiency", 0xBEEF, 4, |rng| {
        let g = gen::small_kg(rng);
        let p = 2 + rng.below(4);
        let hops = 1 + rng.below(2); // 1 or 2
        let cfg = PartitionConfig {
            strategy: PartitionStrategy::Hdrf,
            num_partitions: p,
            hops,
            ..Default::default()
        };
        let parts = partition::partition_graph(&g, &cfg, rng.next_u64());
        let csr = kgscale::graph::Csr::build(g.num_entities, &g.train);
        for part in &parts {
            let have: HashSet<u64> =
                part.core_edges.iter().chain(&part.support_edges).map(Triple::key).collect();
            // BFS distances from core vertices.
            let mut dist = vec![u32::MAX; g.num_entities];
            let mut q = Vec::new();
            for e in &part.core_edges {
                for v in [e.s, e.t] {
                    if dist[v as usize] == u32::MAX {
                        dist[v as usize] = 0;
                        q.push(v);
                    }
                }
            }
            let mut head = 0;
            while head < q.len() {
                let v = q[head];
                head += 1;
                let d = dist[v as usize];
                if d as usize >= hops {
                    continue;
                }
                for &eid in csr.in_edges(v).iter().chain(csr.out_edges(v)) {
                    let e = g.train[eid as usize];
                    assert!(
                        have.contains(&e.key()),
                        "partition {} misses edge incident to dist-{d} vertex",
                        part.id
                    );
                    let w = if e.s == v { e.t } else { e.s };
                    if dist[w as usize] == u32::MAX {
                        dist[w as usize] = d + 1;
                        q.push(w);
                    }
                }
            }
        }
    });
}

/// Negative samples stay inside the core-vertex domain and never collide
/// with partition positives (modulo the bounded-retry fallback).
#[test]
fn prop_negative_sampler_domain() {
    prop_check("negative-domain", 0xDEAD, 5, |rng| {
        let g = gen::small_kg(rng);
        let p = gen::partitions(rng);
        let cfg = PartitionConfig {
            strategy: PartitionStrategy::Hdrf,
            num_partitions: p,
            ..Default::default()
        };
        let parts = partition::partition_graph(&g, &cfg, rng.next_u64());
        for part in &parts {
            let ctx = PartContext::new(part);
            let core: HashSet<u32> = ctx.core_vertices.iter().copied().collect();
            let sampler = NegativeSampler::new(&ctx, Scope::LocalCore, g.num_entities);
            let mut srng = Rng::seeded(rng.next_u64());
            let (negs, remote) = sampler.sample_epoch(&ctx, 2, &mut srng);
            assert_eq!(remote, 0);
            assert_eq!(negs.len(), ctx.core_edges.len() * 2);
            for n in &negs {
                assert!(core.contains(&n.s) && core.contains(&n.t));
                assert!(n.s != n.t, "self-loop negative");
            }
        }
    });
}

/// Batching covers every triple exactly once with correct labels.
#[test]
fn prop_batching_partition_of_epoch() {
    prop_check("batching-exact-cover", 0xFACE, 5, |rng| {
        let g = gen::small_kg(rng);
        let cfg = PartitionConfig {
            strategy: PartitionStrategy::Hdrf,
            num_partitions: 1 + rng.below(4),
            ..Default::default()
        };
        let parts = partition::partition_graph(&g, &cfg, 7);
        let ctx = PartContext::new(&parts[0]);
        let sampler = NegativeSampler::new(&ctx, Scope::LocalCore, g.num_entities);
        let mut srng = Rng::seeded(rng.next_u64());
        let s = 1 + rng.below(3);
        let (negs, _) = sampler.sample_epoch(&ctx, s, &mut srng);
        let batch_pos = [0usize, 16, 64][rng.below(3)];
        let ep = EpochBatches::build(&ctx, negs, batch_pos, &mut srng);
        let total: usize = ep.iter().map(|b| b.len()).sum();
        assert_eq!(total, ctx.core_edges.len() * (1 + s));
        let pos = ep.iter().flatten().filter(|t| t.label == 1.0).count();
        assert_eq!(pos, ctx.core_edges.len());
    });
}

/// The compute graph of a batch contains every batch endpoint, edge
/// indices in range, and grows monotonically with hops.
#[test]
fn prop_compute_graph_well_formed() {
    prop_check("compute-graph-well-formed", 0xF00D, 5, |rng| {
        let g = gen::small_kg(rng);
        let cfg = PartitionConfig {
            strategy: PartitionStrategy::Hdrf,
            num_partitions: 1 + rng.below(3),
            ..Default::default()
        };
        let parts = partition::partition_graph(&g, &cfg, 3);
        for part in parts.iter().take(2) {
            let ctx = PartContext::new(part);
            if ctx.core_edges.is_empty() {
                continue;
            }
            let mut builder = ComputeGraphBuilder::new(&ctx);
            let take = (1 + rng.below(32)).min(ctx.core_edges.len());
            let batch: Vec<_> = ctx.core_edges[..take]
                .iter()
                .map(|e| kgscale::sampler::TrainTriple { s: e.s, r: e.r, t: e.t, label: 1.0 })
                .collect();
            let mut prev_nodes = 0;
            for hops in 1..=2 {
                let cg = builder.build(&ctx, &batch, hops, g.num_relations);
                assert!(cg.num_nodes() >= prev_nodes);
                prev_nodes = cg.num_nodes();
                let n = cg.num_nodes() as i32;
                for i in 0..cg.num_edges() {
                    assert!(cg.src[i] < n && cg.dst[i] < n);
                    assert!((cg.rel[i] as usize) < 2 * g.num_relations);
                }
                for i in 0..cg.num_triples() {
                    assert!(cg.ts[i] < n && cg.tt[i] < n);
                }
            }
        }
    });
}

/// Ring AllReduce == serial sum == parameter-server, under random sizes.
#[test]
fn prop_allreduce_equivalence() {
    prop_check("allreduce-equivalence", 0xAB5E, 8, |rng| {
        let p = 2 + rng.below(7);
        let n = 1 + rng.below(2000);
        let mut bufs: Vec<Vec<f32>> = (0..p)
            .map(|_| (0..n).map(|_| rng.uniform_f32(-2.0, 2.0)).collect())
            .collect();
        let mut serial = vec![0f32; n];
        for b in &bufs {
            for (s, x) in serial.iter_mut().zip(b) {
                *s += x;
            }
        }
        let mut ps = bufs.clone();
        ring_allreduce_sum(&mut bufs);
        param_server_sum(&mut ps);
        for w in 0..p {
            for i in 0..n {
                let tol = 1e-4 * serial[i].abs().max(1.0);
                assert!((bufs[w][i] - serial[i]).abs() <= tol, "ring diverges at [{w}][{i}]");
                assert!((ps[w][i] - serial[i]).abs() <= tol, "ps diverges at [{w}][{i}]");
            }
        }
    });
}

/// `EpochBatches::batch(i)` is a zero-copy view of exactly the i-th
/// chunk the iterator yields, and `None` past the end — the pipelined
/// trainer indexes batches directly instead of re-collecting the epoch.
#[test]
fn prop_batch_accessor_matches_iteration() {
    prop_check("batch-accessor", 0xBA7C4, 5, |rng| {
        let g = gen::small_kg(rng);
        let cfg = PartitionConfig {
            strategy: PartitionStrategy::Hdrf,
            num_partitions: 1 + rng.below(3),
            ..Default::default()
        };
        let parts = partition::partition_graph(&g, &cfg, rng.next_u64());
        let ctx = PartContext::new(&parts[0]);
        let sampler = NegativeSampler::new(&ctx, Scope::LocalCore, g.num_entities);
        let mut srng = Rng::seeded(rng.next_u64());
        let (negs, _) = sampler.sample_epoch(&ctx, 1, &mut srng);
        let batch_pos = [0usize, 16, 64][rng.below(3)];
        let ep = EpochBatches::build(&ctx, negs, batch_pos, &mut srng);
        for (i, chunk) in ep.iter().enumerate() {
            assert_eq!(ep.batch(i), Some(chunk), "batch {i} differs from iterator");
        }
        assert_eq!(ep.iter().count(), ep.num_batches());
        assert!(ep.batch(ep.num_batches()).is_none());
    });
}

/// The per-(epoch, wid) RNG seeds driving epoch planning are pairwise
/// distinct over a realistic grid — a collision would hand two workers
/// (or two epochs) identical negative samples and batch shuffles.
#[test]
fn worker_epoch_seeds_pairwise_distinct() {
    for base in [0u64, 7, 42, u64::MAX / 3] {
        let mut seen = HashSet::new();
        for epoch in 0..64 {
            for wid in 0..16 {
                assert!(
                    seen.insert(kgscale::train::worker_epoch_seed(base, epoch, wid)),
                    "seed collision at base={base} epoch={epoch} wid={wid}"
                );
            }
        }
    }
}

/// The host prep pool runs every submitted job exactly once and joins
/// its threads on drop (no lost or duplicated prep work).
#[test]
fn host_pool_completes_all_jobs() {
    use std::sync::mpsc;
    for threads in [1usize, 4] {
        let (tx, rx) = mpsc::channel();
        {
            let pool = kgscale::train::HostPool::new(threads);
            assert_eq!(pool.threads(), threads);
            for i in 0..100u32 {
                let tx = tx.clone();
                pool.submit(move || tx.send(i).expect("collector alive"));
            }
            // Dropping the pool joins all workers, so every job has run.
        }
        drop(tx);
        let mut got: Vec<u32> = rx.iter().collect();
        got.sort_unstable();
        assert_eq!(got, (0..100).collect::<Vec<_>>(), "threads={threads}");
    }
}

/// Determinism: the full pipeline (partition -> sample -> batch -> CG)
/// is bit-identical across runs with the same seeds.
#[test]
fn prop_pipeline_determinism() {
    prop_check("pipeline-determinism", 0x5EED, 3, |rng| {
        let g = gen::small_kg(rng);
        let seed = rng.next_u64();
        let run = |g: &kgscale::graph::KnowledgeGraph| {
            let cfg = PartitionConfig {
                strategy: PartitionStrategy::Hdrf,
                num_partitions: 3,
                ..Default::default()
            };
            let parts = partition::partition_graph(g, &cfg, seed);
            let ctx = PartContext::new(&parts[1]);
            let sampler = NegativeSampler::new(&ctx, Scope::LocalCore, g.num_entities);
            let mut srng = Rng::seeded(seed);
            let (negs, _) = sampler.sample_epoch(&ctx, 1, &mut srng);
            let ep = EpochBatches::build(&ctx, negs, 32, &mut srng);
            let mut builder = ComputeGraphBuilder::new(&ctx);
            let first = ep.iter().next().unwrap();
            let cg = builder.build(&ctx, first, 2, g.num_relations);
            (cg.nodes_global.clone(), cg.src.clone(), cg.rel.clone(), cg.labels.clone())
        };
        assert_eq!(run(&g), run(&g));
    });
}

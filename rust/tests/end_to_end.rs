//! End-to-end integration tests over the real AOT artifacts (tiny tier).
//! These require `make artifacts` to have produced `artifacts/tiny/`;
//! they are skipped (with a loud message) when artifacts are missing so
//! `cargo test` still works on a fresh checkout.

use kgscale::config::ExperimentConfig;
use kgscale::eval::{self, FilterIndex};
use kgscale::graph::generator;
use kgscale::model::Manifest;
use kgscale::runtime::Runtime;
use kgscale::train::Trainer;
use std::path::Path;

fn artifacts() -> Option<(Runtime, Manifest)> {
    let dir = Path::new("artifacts/tiny");
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: artifacts/tiny missing — run `make artifacts`");
        return None;
    }
    let manifest = Manifest::load(dir).expect("manifest parses");
    let runtime = Runtime::new(dir).expect("PJRT cpu client");
    Some((runtime, manifest))
}

#[test]
fn training_reduces_loss_and_is_deterministic() {
    let Some((runtime, manifest)) = artifacts() else { return };
    let cfg = ExperimentConfig::tiny();
    let g = generator::generate(&cfg.dataset);
    let run = |seed: u64| -> (Vec<f64>, Vec<f32>) {
        let mut c = cfg.clone();
        c.train.seed = seed;
        let mut t = Trainer::new(c, &g, &runtime, manifest.clone()).unwrap();
        let mut losses = Vec::new();
        for _ in 0..8 {
            losses.push(t.train_epoch().unwrap().mean_loss);
        }
        (losses, t.params)
    };
    let (losses_a, params_a) = run(7);
    let (losses_b, params_b) = run(7);
    let (losses_c, _) = run(8);
    assert_eq!(losses_a, losses_b, "same seed must reproduce exactly");
    assert_eq!(params_a, params_b);
    assert_ne!(losses_a, losses_c, "different seed must differ");
    assert!(
        losses_a.last().unwrap() < &(losses_a[0] * 0.99),
        "loss did not decrease: {losses_a:?}"
    );
}

/// The paper's §2.2 mathematical-equivalence requirement: distributed
/// training with P workers must match single-worker training on the same
/// total data. We verify the *gradient* path by comparing full-batch
/// P=1 vs P=2 training where both see identical positives and the same
/// global count normalization. Partitioned negatives differ by
/// construction (the constraint-based sampler is per-partition), so the
/// strict check trains with 0 epochs of negatives... instead we check
/// the weaker-but-meaningful property the paper reports: final losses
/// land in the same regime and both runs learn.
#[test]
fn distributed_training_parity() {
    let Some((runtime, manifest)) = artifacts() else { return };
    let cfg = ExperimentConfig::tiny();
    let g = generator::generate(&cfg.dataset);
    let mut results = Vec::new();
    for p in [1usize, 2, 4] {
        let mut c = cfg.clone();
        c.train.num_trainers = p;
        let mut t = Trainer::new(c, &g, &runtime, manifest.clone()).unwrap();
        let mut last = f64::NAN;
        for _ in 0..10 {
            last = t.train_epoch().unwrap().mean_loss;
        }
        results.push(last);
    }
    let base = results[0];
    for (i, &r) in results.iter().enumerate() {
        assert!(
            (r - base).abs() < 0.08,
            "P={} final loss {r:.4} far from P=1 {base:.4} (all: {results:?})",
            [1, 2, 4][i]
        );
    }
}

#[test]
fn evaluation_improves_with_training() {
    let Some((runtime, manifest)) = artifacts() else { return };
    let cfg = ExperimentConfig::tiny();
    let g = generator::generate(&cfg.dataset);
    let filter = FilterIndex::build(&g);
    let mut t = Trainer::new(cfg.clone(), &g, &runtime, manifest.clone()).unwrap();
    let before =
        eval::evaluate(&runtime, &manifest, &t.params, &g, &filter, &g.test).unwrap();
    for _ in 0..25 {
        t.train_epoch().unwrap();
    }
    let after =
        eval::evaluate(&runtime, &manifest, &t.params, &g, &filter, &g.test).unwrap();
    assert!(
        after.mrr > before.mrr,
        "training did not improve MRR: {:.4} -> {:.4}",
        before.mrr,
        after.mrr
    );
    // Metric sanity.
    assert!(after.hits1 <= after.hits3 && after.hits3 <= after.hits10);
    assert!(after.mrr > 0.0 && after.mrr <= 1.0);
    assert_eq!(after.num_queries, 2 * g.test.len());
}

#[test]
fn encode_shapes_and_score_consistency() {
    let Some((runtime, manifest)) = artifacts() else { return };
    let cfg = ExperimentConfig::tiny();
    let g = generator::generate(&cfg.dataset);
    let params = kgscale::model::init_params(&manifest, 1);
    let h = eval::encode_full_graph(&runtime, &manifest, &params, &g).unwrap();
    let (_, n_pad, _) = manifest.encode_entry().unwrap();
    assert_eq!(h.len(), n_pad * manifest.embed_dim);
    assert!(h.iter().all(|x| x.is_finite()));
    // Embeddings of real entities should not be all identical.
    let d = manifest.embed_dim;
    assert_ne!(&h[0..d], &h[d..2 * d]);
}

#[test]
fn virtual_time_accounts_sync_cost() {
    let Some((runtime, manifest)) = artifacts() else { return };
    let cfg = ExperimentConfig::tiny();
    let g = generator::generate(&cfg.dataset);
    // With GradSync::None the modeled sync time disappears; Ring adds it.
    let time_with = {
        let mut c = cfg.clone();
        c.train.num_trainers = 4;
        c.train.grad_sync = kgscale::config::GradSync::Ring;
        c.network.latency_us = 50_000.0; // exaggerate to dominate
        let mut t = Trainer::new(c, &g, &runtime, manifest.clone()).unwrap();
        t.train_epoch().unwrap().virtual_secs
    };
    let time_without = {
        let mut c = cfg.clone();
        c.train.num_trainers = 4;
        c.train.grad_sync = kgscale::config::GradSync::None;
        let mut t = Trainer::new(c, &g, &runtime, manifest.clone()).unwrap();
        t.train_epoch().unwrap().virtual_secs
    };
    assert!(
        time_with > time_without + 0.2,
        "ring sync must show up in virtual time: {time_with:.3} vs {time_without:.3}"
    );
}

//! End-to-end integration tests over the real AOT artifacts (tiny tier).
//! These require `make artifacts` to have produced `artifacts/tiny/`;
//! they are skipped (with a loud message) when artifacts are missing so
//! `cargo test` still works on a fresh checkout.

use kgscale::config::{ExperimentConfig, GradMode, GradSync};
use kgscale::eval::{self, FilterIndex};
use kgscale::graph::generator;
use kgscale::model::Manifest;
use kgscale::runtime::Runtime;
use kgscale::train::Trainer;
use std::path::Path;

fn artifacts() -> Option<(Runtime, Manifest)> {
    let dir = Path::new("artifacts/tiny");
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: artifacts/tiny missing — run `make artifacts`");
        return None;
    }
    let manifest = Manifest::load(dir).expect("manifest parses");
    let runtime = Runtime::new(dir).expect("PJRT cpu client");
    Some((runtime, manifest))
}

#[test]
fn training_reduces_loss_and_is_deterministic() {
    let Some((runtime, manifest)) = artifacts() else { return };
    let cfg = ExperimentConfig::tiny();
    let g = generator::generate(&cfg.dataset);
    let run = |seed: u64| -> (Vec<f64>, Vec<f32>) {
        let mut c = cfg.clone();
        c.train.seed = seed;
        let mut t = Trainer::new(c, &g, &runtime, manifest.clone()).unwrap();
        let mut losses = Vec::new();
        for _ in 0..8 {
            losses.push(t.train_epoch().unwrap().mean_loss);
        }
        (losses, t.params)
    };
    let (losses_a, params_a) = run(7);
    let (losses_b, params_b) = run(7);
    let (losses_c, _) = run(8);
    assert_eq!(losses_a, losses_b, "same seed must reproduce exactly");
    assert_eq!(params_a, params_b);
    assert_ne!(losses_a, losses_c, "different seed must differ");
    assert!(
        losses_a.last().unwrap() < &(losses_a[0] * 0.99),
        "loss did not decrease: {losses_a:?}"
    );
}

/// The paper's §2.2 mathematical-equivalence requirement: distributed
/// training with P workers must match single-worker training on the same
/// total data. We verify the *gradient* path by comparing full-batch
/// P=1 vs P=2 training where both see identical positives and the same
/// global count normalization. Partitioned negatives differ by
/// construction (the constraint-based sampler is per-partition), so the
/// strict check trains with 0 epochs of negatives... instead we check
/// the weaker-but-meaningful property the paper reports: final losses
/// land in the same regime and both runs learn.
#[test]
fn distributed_training_parity() {
    let Some((runtime, manifest)) = artifacts() else { return };
    let cfg = ExperimentConfig::tiny();
    let g = generator::generate(&cfg.dataset);
    let mut results = Vec::new();
    for p in [1usize, 2, 4] {
        let mut c = cfg.clone();
        c.train.num_trainers = p;
        let mut t = Trainer::new(c, &g, &runtime, manifest.clone()).unwrap();
        let mut last = f64::NAN;
        for _ in 0..10 {
            last = t.train_epoch().unwrap().mean_loss;
        }
        results.push(last);
    }
    let base = results[0];
    for (i, &r) in results.iter().enumerate() {
        assert!(
            (r - base).abs() < 0.08,
            "P={} final loss {r:.4} far from P=1 {base:.4} (all: {results:?})",
            [1, 2, 4][i]
        );
    }
}

#[test]
fn evaluation_improves_with_training() {
    let Some((runtime, manifest)) = artifacts() else { return };
    let cfg = ExperimentConfig::tiny();
    let g = generator::generate(&cfg.dataset);
    let filter = FilterIndex::build(&g).unwrap();
    let mut t = Trainer::new(cfg.clone(), &g, &runtime, manifest.clone()).unwrap();
    let before =
        eval::evaluate(&runtime, &manifest, &t.params, &g, &filter, &g.test).unwrap();
    for _ in 0..25 {
        t.train_epoch().unwrap();
    }
    let after =
        eval::evaluate(&runtime, &manifest, &t.params, &g, &filter, &g.test).unwrap();
    assert!(
        after.mrr > before.mrr,
        "training did not improve MRR: {:.4} -> {:.4}",
        before.mrr,
        after.mrr
    );
    // Metric sanity.
    assert!(after.hits1 <= after.hits3 && after.hits3 <= after.hits10);
    assert!(after.mrr > 0.0 && after.mrr <= 1.0);
    assert_eq!(after.num_queries, 2 * g.test.len());
}

#[test]
fn encode_shapes_and_score_consistency() {
    let Some((runtime, manifest)) = artifacts() else { return };
    let cfg = ExperimentConfig::tiny();
    let g = generator::generate(&cfg.dataset);
    let params = kgscale::model::init_params(&manifest, 1);
    let h = eval::encode_full_graph(&runtime, &manifest, &params, &g).unwrap();
    let (_, n_pad, _) = manifest.encode_entry().unwrap();
    assert_eq!(h.len(), n_pad * manifest.embed_dim);
    assert!(h.iter().all(|x| x.is_finite()));
    // Embeddings of real entities should not be all identical.
    let d = manifest.embed_dim;
    assert_ne!(&h[0..d], &h[d..2 * d]);
}

#[test]
fn virtual_time_accounts_sync_cost() {
    let Some((runtime, manifest)) = artifacts() else { return };
    let cfg = ExperimentConfig::tiny();
    let g = generator::generate(&cfg.dataset);
    // With GradSync::None the modeled sync time disappears; Ring adds it.
    let time_with = {
        let mut c = cfg.clone();
        c.train.num_trainers = 4;
        c.train.grad_sync = kgscale::config::GradSync::Ring;
        c.network.latency_us = 50_000.0; // exaggerate to dominate
        let mut t = Trainer::new(c, &g, &runtime, manifest.clone()).unwrap();
        t.train_epoch().unwrap().virtual_secs
    };
    let time_without = {
        let mut c = cfg.clone();
        c.train.num_trainers = 4;
        c.train.grad_sync = kgscale::config::GradSync::None;
        let mut t = Trainer::new(c, &g, &runtime, manifest.clone()).unwrap();
        t.train_epoch().unwrap().virtual_secs
    };
    assert!(
        time_with > time_without + 0.2,
        "ring sync must show up in virtual time: {time_with:.3} vs {time_without:.3}"
    );
}

/// Shared harness for the gradient-mode tests: mini-batches + 2 workers
/// so sparse accumulation, multi-worker ordering, and per-step touched
/// sets are all exercised (the tiny default `batch_edges = 0` is
/// full-batch, which would touch every row and make the test vacuous).
fn run_mode(
    runtime: &Runtime,
    manifest: &Manifest,
    g: &kgscale::graph::KnowledgeGraph,
    mode: GradMode,
    sync: GradSync,
) -> (Vec<f64>, Vec<f32>, f64, f64) {
    let mut c = ExperimentConfig::tiny();
    c.train.batch_edges = 64;
    c.train.num_trainers = 2;
    c.train.grad_mode = mode;
    c.train.grad_sync = sync;
    let mut t = Trainer::new(c, g, runtime, manifest.clone()).unwrap();
    let mut losses = Vec::new();
    let (mut touched, mut sync_bytes) = (0.0, 0.0);
    for _ in 0..6 {
        let r = t.train_epoch().unwrap();
        touched = r.avg_touched_rows;
        sync_bytes = r.avg_sync_bytes;
        losses.push(r.mean_loss);
    }
    (losses, t.params, touched, sync_bytes)
}

/// The pipelined host data path's central contract: with any
/// `host_threads` setting, training is *bit-identical* to the
/// `host_threads = 0` sequential reference — same losses, same final
/// parameters — for every gradient mode. Overlap only changes *when*
/// batches are prepared, never their contents or accumulation order.
#[test]
fn pipelined_path_bit_identical_to_sequential() {
    let Some((runtime, manifest)) = artifacts() else { return };
    let g = generator::generate(&ExperimentConfig::tiny().dataset);
    let run = |mode: GradMode, threads: usize| -> (Vec<f64>, Vec<f32>, Vec<f64>, Vec<f64>) {
        let mut c = ExperimentConfig::tiny();
        c.train.batch_edges = 64;
        c.train.num_trainers = 2;
        c.train.grad_mode = mode;
        c.train.grad_sync = GradSync::Ring;
        c.train.host_threads = threads;
        c.train.prefetch_depth = 2;
        let mut t = Trainer::new(c, &g, &runtime, manifest.clone()).unwrap();
        let (mut losses, mut stalls, mut overlaps) = (Vec::new(), Vec::new(), Vec::new());
        for _ in 0..3 {
            let r = t.train_epoch().unwrap();
            losses.push(r.mean_loss);
            stalls.push(r.prefetch_stall_secs);
            overlaps.push(r.overlap_efficiency);
        }
        (losses, t.params, stalls, overlaps)
    };
    for mode in [GradMode::Dense, GradMode::Sparse, GradMode::SparseLazy] {
        let (seq_losses, seq_params, seq_stalls, seq_overlaps) = run(mode, 0);
        // The sequential path never stalls and reports no overlap.
        assert!(seq_stalls.iter().all(|&s| s == 0.0), "{mode:?}: {seq_stalls:?}");
        assert!(seq_overlaps.iter().all(|&o| o == 0.0), "{mode:?}: {seq_overlaps:?}");
        for threads in [1usize, 3] {
            let (losses, params, stalls, overlaps) = run(mode, threads);
            assert_eq!(
                seq_losses,
                losses,
                "{mode:?}, host_threads={threads}: losses must match sequential bit-for-bit"
            );
            assert_eq!(
                seq_params,
                params,
                "{mode:?}, host_threads={threads}: params must match sequential bit-for-bit"
            );
            assert!(stalls.iter().all(|&s| s >= 0.0));
            assert!(overlaps.iter().all(|&o| (0.0..=1.0).contains(&o)));
        }
    }
}

/// The overlapped eval path's central contract: with any
/// `eval.host_threads` / `eval.prefetch_depth` setting, filtered
/// MRR/Hits@k are *bit-identical* to the `eval.host_threads = 0`
/// sequential reference — ranks are integers and both paths fold them
/// in the same chunk-order, query-order sequence. Also checks the
/// legacy one-shot `eval::evaluate` agrees with the `Evaluator` driver.
#[test]
fn eval_overlapped_bit_identical_to_sequential() {
    let Some((runtime, manifest)) = artifacts() else { return };
    let cfg = ExperimentConfig::tiny();
    let g = generator::generate(&cfg.dataset);
    let filter = FilterIndex::build(&g).unwrap();
    let mut t = Trainer::new(cfg.clone(), &g, &runtime, manifest.clone()).unwrap();
    for _ in 0..3 {
        t.train_epoch().unwrap();
    }

    let run = |threads: usize, depth: usize| {
        let ecfg = kgscale::config::EvalConfig { host_threads: threads, prefetch_depth: depth };
        let mut ev = eval::Evaluator::new(&manifest, &g, &ecfg).unwrap();
        ev.evaluate(&runtime, &manifest, &t.params, &filter, &g.test).unwrap()
    };
    let (want, seq_stats) = run(0, 2);
    assert_eq!(want.num_queries, 2 * g.test.len());
    assert!(seq_stats.num_chunks > 1, "tiny test set should span several chunks");
    // The sequential path never stalls and reports no overlap.
    assert_eq!(seq_stats.rank_stall_secs, 0.0);
    assert_eq!(seq_stats.overlap_efficiency, 0.0);
    assert!(seq_stats.rank_secs > 0.0);

    for (threads, depth) in [(1usize, 1usize), (3, 2), (4, 3)] {
        let (got, stats) = run(threads, depth);
        assert_eq!(got.num_queries, want.num_queries);
        assert_eq!(
            got.mrr.to_bits(),
            want.mrr.to_bits(),
            "threads={threads} depth={depth}: MRR must match sequential bit-for-bit"
        );
        assert_eq!(got.hits1.to_bits(), want.hits1.to_bits());
        assert_eq!(got.hits3.to_bits(), want.hits3.to_bits());
        assert_eq!(got.hits10.to_bits(), want.hits10.to_bits());
        assert_eq!(stats.num_chunks, seq_stats.num_chunks);
        assert!(stats.rank_stall_secs >= 0.0);
        assert!((0.0..=1.0).contains(&stats.overlap_efficiency));
    }

    // Legacy one-shot entry point agrees with the cached driver.
    let legacy = eval::evaluate(&runtime, &manifest, &t.params, &g, &filter, &g.test).unwrap();
    assert_eq!(legacy.mrr.to_bits(), want.mrr.to_bits());
    assert_eq!(legacy.hits10.to_bits(), want.hits10.to_bits());
}

/// The row-sparse gradient path's central claim: `sparse` (row-sparse
/// accumulation + dense Adam) is *bit-identical* to the `dense`
/// reference — same losses, same parameters — because rows outside the
/// batch's compute graph have exactly-zero gradients either way.
#[test]
fn gradient_mode_sparse_is_bit_identical_to_dense() {
    let Some((runtime, manifest)) = artifacts() else { return };
    let g = generator::generate(&ExperimentConfig::tiny().dataset);
    let (dl, dp, dt, _) = run_mode(&runtime, &manifest, &g, GradMode::Dense, GradSync::Ring);
    let (sl, sp, st, _) = run_mode(&runtime, &manifest, &g, GradMode::Sparse, GradSync::Ring);
    assert_eq!(dl, sl, "sparse-mode losses must match dense bit-for-bit");
    assert_eq!(dp, sp, "sparse-mode params must match dense bit-for-bit");
    // Dense mode does not track touched rows; sparse must.
    assert_eq!(dt, 0.0);
    assert!(st > 0.0, "sparse mode should report touched rows");
    assert!(
        st <= ExperimentConfig::tiny().dataset.entities as f64,
        "touched rows bounded by the entity table: {st}"
    );
}

/// Lazy Adam is documented as *not* bit-equivalent, but its loss
/// trajectory must track the dense path and still learn.
#[test]
fn gradient_mode_lazy_adam_tracks_dense_trajectory() {
    let Some((runtime, manifest)) = artifacts() else { return };
    let g = generator::generate(&ExperimentConfig::tiny().dataset);
    let (dl, _, _, _) = run_mode(&runtime, &manifest, &g, GradMode::Dense, GradSync::Ring);
    let (ll, _, lt, _) =
        run_mode(&runtime, &manifest, &g, GradMode::SparseLazy, GradSync::Ring);
    assert!(lt > 0.0);
    assert!(
        ll.last().unwrap() < &(ll[0] * 0.99),
        "lazy Adam did not learn: {ll:?}"
    );
    for (e, (d, l)) in dl.iter().zip(ll.iter()).enumerate() {
        assert!(
            (d - l).abs() < 0.08,
            "epoch {e}: lazy loss {l:.4} far from dense {d:.4} (dense {dl:?}, lazy {ll:?})"
        );
    }
}

/// A process-unique scratch directory for checkpoint tests, cleared of
/// any debris from a previous (crashed) run of the same test binary.
fn temp_ckpt_dir(tag: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("kgscale-e2e-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

/// Invariant 1 of the fault layer: `faults.enabled = false` is
/// *bit-identical* to a config that never mentions `[faults]` at all —
/// for every gradient mode and on both the sequential and pipelined
/// host paths — and reports exactly-zero recovery metrics. The disabled
/// configs carry aggressive rates to prove nothing leaks past the gate.
#[test]
fn fault_layer_disabled_is_bit_identical() {
    let Some((runtime, manifest)) = artifacts() else { return };
    let g = generator::generate(&ExperimentConfig::tiny().dataset);
    let run = |mode: GradMode, threads: usize, hot_but_disabled: bool| {
        let mut c = ExperimentConfig::tiny();
        c.train.batch_edges = 64;
        c.train.num_trainers = 2;
        c.train.grad_mode = mode;
        c.train.grad_sync = GradSync::Ring;
        c.train.host_threads = threads;
        c.train.prefetch_depth = 2;
        if hot_but_disabled {
            c.faults.enabled = false; // the gate under test
            c.faults.crash_rate = 1.0;
            c.faults.straggler_rate = 1.0;
            c.faults.link_degrade_rate = 1.0;
            c.faults.slowdown_factor = 16.0;
            c.faults.link_degrade_factor = 16.0;
        }
        let mut t = Trainer::new(c, &g, &runtime, manifest.clone()).unwrap();
        let mut losses = Vec::new();
        for _ in 0..3 {
            losses.push(t.train_epoch().unwrap().mean_loss);
        }
        let h = t.history.clone();
        (losses, t.params, h)
    };
    for mode in [GradMode::Dense, GradMode::Sparse, GradMode::SparseLazy] {
        let (base_losses, base_params, _) = run(mode, 0, false);
        for threads in [0usize, 2] {
            let (losses, params, h) = run(mode, threads, true);
            assert_eq!(
                base_losses, losses,
                "{mode:?}, host_threads={threads}: disabled faults must not change losses"
            );
            assert_eq!(
                base_params, params,
                "{mode:?}, host_threads={threads}: disabled faults must not change params"
            );
            assert_eq!(h.total_recoveries(), 0);
            assert_eq!(h.total_replayed_steps(), 0);
            assert_eq!(h.total_recovery_secs(), 0.0);
            assert_eq!(h.total_checkpoint_write_secs(), 0.0);
            assert!(h.epochs.iter().all(|e| e.straggler_secs == 0.0));
        }
    }
}

/// Invariant 2: a run that crashes and recovers reproduces the
/// *exact* fault-free loss/parameter trajectory. Crashes never corrupt
/// the live replica (the survivors deterministically replay the lost
/// worker's state); stragglers and link degradation only stretch the
/// virtual clock. Also pins that the recovery metrics show up in
/// `EpochRecord` and in the report table.
#[test]
fn crash_recovery_preserves_fault_free_trajectory() {
    let Some((runtime, manifest)) = artifacts() else { return };
    let g = generator::generate(&ExperimentConfig::tiny().dataset);
    let base_cfg = || {
        let mut c = ExperimentConfig::tiny();
        c.train.batch_edges = 64;
        c.train.num_trainers = 2;
        c.train.grad_sync = GradSync::Ring;
        c
    };

    // Fault-free reference.
    let mut clean = Trainer::new(base_cfg(), &g, &runtime, manifest.clone()).unwrap();
    let mut clean_losses = Vec::new();
    for _ in 0..6 {
        clean_losses.push(clean.train_epoch().unwrap().mean_loss);
    }

    // Same run under an aggressive fault plan with checkpointing on.
    let dir = temp_ckpt_dir("faulted");
    let mut c = base_cfg();
    c.train.checkpoint_every_epochs = 2;
    c.train.checkpoint_dir = dir.to_string_lossy().into_owned();
    c.faults.enabled = true;
    c.faults.seed = 0xFA17;
    c.faults.crash_rate = 0.2;
    c.faults.straggler_rate = 0.5;
    c.faults.link_degrade_rate = 0.5;
    c.validate().unwrap();
    let mut faulted = Trainer::new(c, &g, &runtime, manifest.clone()).unwrap();
    let mut faulted_losses = Vec::new();
    for _ in 0..6 {
        faulted_losses.push(faulted.train_epoch().unwrap().mean_loss);
    }

    assert_eq!(
        clean_losses, faulted_losses,
        "recovered run must reproduce the fault-free loss trajectory exactly"
    );
    assert_eq!(
        clean.params, faulted.params,
        "recovered run must reproduce the fault-free parameters bit-for-bit"
    );

    // The fault plan at these rates must actually have fired, and every
    // recovery must carry its accounting.
    let h = &faulted.history;
    assert!(h.total_recoveries() > 0, "crash_rate 0.2 over 6 epochs never fired");
    assert!(h.total_replayed_steps() > 0);
    assert!(h.total_recovery_secs() > 0.0);
    assert!(h.total_checkpoint_write_secs() > 0.0, "periodic checkpoints were never written");
    assert!(h.epochs.iter().any(|e| e.straggler_secs > 0.0), "stragglers never fired");
    for e in h.epochs.iter().filter(|e| e.fault_recoveries > 0) {
        assert!(e.replayed_steps > 0 && e.recovery_secs > 0.0);
    }
    let table = kgscale::experiments::recovery_table(h, "e2e").to_markdown();
    assert!(table.contains("crashes"), "recovery report missing: {table}");
    let _ = std::fs::remove_dir_all(&dir);
}

/// `--resume`: restoring the newest checkpoint from disk and training
/// onward is bit-identical to the uninterrupted run, and a grad-mode
/// mismatch on resume is rejected loudly rather than silently mixing
/// optimizer semantics.
#[test]
fn resume_from_disk_reproduces_uninterrupted_run() {
    let Some((runtime, manifest)) = artifacts() else { return };
    let g = generator::generate(&ExperimentConfig::tiny().dataset);
    let dir = temp_ckpt_dir("resume");
    let mk = |every: usize, mode: GradMode| {
        let mut c = ExperimentConfig::tiny();
        c.train.batch_edges = 64;
        c.train.num_trainers = 2;
        c.train.grad_sync = GradSync::Ring;
        c.train.grad_mode = mode;
        c.train.checkpoint_every_epochs = every;
        if every > 0 {
            c.train.checkpoint_dir = dir.to_string_lossy().into_owned();
        }
        Trainer::new(c, &g, &runtime, manifest.clone()).unwrap()
    };

    // Uninterrupted reference: 6 epochs straight through.
    let mut a = mk(0, GradMode::Dense);
    let mut a_losses = Vec::new();
    for _ in 0..6 {
        a_losses.push(a.train_epoch().unwrap().mean_loss);
    }

    // Interrupted run: 4 epochs (checkpoints at tags 0, 2, 4), then the
    // process "dies" (trainer dropped) and a fresh one resumes.
    let mut b = mk(2, GradMode::Dense);
    for _ in 0..4 {
        b.train_epoch().unwrap();
    }
    drop(b);
    let mut b2 = mk(2, GradMode::Dense);
    let resumed = b2.resume_from_dir(&dir).unwrap();
    assert_eq!(resumed, 4, "latest checkpoint should be the epoch-4 boundary");
    assert_eq!(b2.completed_epochs(), 4);
    let mut b2_losses = Vec::new();
    for _ in 0..2 {
        b2_losses.push(b2.train_epoch().unwrap().mean_loss);
    }
    assert_eq!(
        &a_losses[4..],
        &b2_losses[..],
        "resumed epochs must match the uninterrupted run bit-for-bit"
    );
    assert_eq!(a.params, b2.params, "resumed params must match bit-for-bit");

    // Lazy Adam cannot adopt a dense/sparse snapshot: its skipped-step
    // replay makes the optimizer state non-equivalent.
    let mut lazy = mk(0, GradMode::SparseLazy);
    let err = lazy.resume_from_dir(&dir).unwrap_err();
    assert!(
        format!("{err:#}").contains("grad_mode"),
        "mismatch error should name grad_mode: {err:#}"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

/// Under `grad_sync = "sparse"` the reported wire bytes follow the
/// touched-row accounting exactly: touched entity rows × (dim·4 + 4
/// index bytes) + touched relation rows × (dim·4 + 4) + the dense
/// remainder outside both tables.
#[test]
fn sparse_sync_reports_touched_row_bytes() {
    let Some((runtime, manifest)) = artifacts() else { return };
    let g = generator::generate(&ExperimentConfig::tiny().dataset);
    let (_, _, _, ring_bytes) =
        run_mode(&runtime, &manifest, &g, GradMode::Sparse, GradSync::Ring);
    assert_eq!(ring_bytes, (manifest.param_count * 4) as f64);
    let (_, _, touched, sparse_bytes) =
        run_mode(&runtime, &manifest, &g, GradMode::Sparse, GradSync::Sparse);
    let ent = manifest.embedding_segment().expect("tiny manifest has ent_emb");
    // Mirror the trainer's guard: the relation table only counts as a
    // sparse segment when it follows the entity table in the layout.
    match manifest.relation_segment().filter(|r| r.offset >= ent.end()) {
        Some(rel) => {
            let rest = manifest.param_count - ent.len() - rel.len();
            let base = touched * (ent.dim * 4 + 4) as f64 + (rest * 4) as f64;
            let rel_cap = (rel.rows * (rel.dim * 4 + 4)) as f64;
            // Every step touches at least one relation row and at most
            // the whole table; the epoch mean sits strictly between.
            assert!(
                sparse_bytes > base,
                "sparse bytes {sparse_bytes} missing relation rows (base {base})"
            );
            assert!(
                sparse_bytes <= base + rel_cap,
                "sparse bytes {sparse_bytes} exceed full-table bound {}",
                base + rel_cap
            );
        }
        None => {
            // 1-D rel_dec: everything outside ent_emb is dense tail.
            let tail = manifest.param_count - ent.len();
            let expect = touched * (ent.dim * 4 + 4) as f64 + (tail * 4) as f64;
            assert!(
                (sparse_bytes - expect).abs() < 1e-6 * expect.max(1.0),
                "sparse bytes {sparse_bytes} != touched-row accounting {expect}"
            );
        }
    }
}

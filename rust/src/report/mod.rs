//! Report emitters: markdown tables (matching the paper's table layout)
//! and series (CSV + ASCII sparklines) for figures. Every experiment
//! harness returns these, and the CLI/examples print and archive them
//! under `results/`.

use std::fmt::Write as _;

/// A markdown table.
#[derive(Clone, Debug)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch in {:?}", self.title);
        self.rows.push(cells);
    }

    pub fn to_markdown(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let _ = writeln!(out, "### {}\n", self.title);
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::from("|");
            for (c, w) in cells.iter().zip(widths) {
                let _ = write!(line, " {c:<w$} |");
            }
            line
        };
        let _ = writeln!(out, "{}", fmt_row(&self.headers, &widths));
        let mut sep = String::from("|");
        for w in &widths {
            let _ = write!(sep, "{}|", "-".repeat(w + 2));
        }
        let _ = writeln!(out, "{sep}");
        for row in &self.rows {
            let _ = writeln!(out, "{}", fmt_row(row, &widths));
        }
        out
    }
}

/// A named data series (one figure line).
#[derive(Clone, Debug)]
pub struct Series {
    pub label: String,
    pub points: Vec<(f64, f64)>,
}

/// A figure: multiple series over a shared axis.
#[derive(Clone, Debug)]
pub struct Figure {
    pub title: String,
    pub x_label: String,
    pub y_label: String,
    pub series: Vec<Series>,
}

impl Figure {
    pub fn new(title: &str, x_label: &str, y_label: &str) -> Figure {
        Figure {
            title: title.to_string(),
            x_label: x_label.to_string(),
            y_label: y_label.to_string(),
            series: Vec::new(),
        }
    }

    pub fn add(&mut self, label: &str, points: Vec<(f64, f64)>) {
        self.series.push(Series { label: label.to_string(), points });
    }

    /// CSV: `x,<label1>,<label2>,...` — series aligned by point index if
    /// they share x values, else long form `label,x,y`.
    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "# {} — x: {}, y: {}", self.title, self.x_label, self.y_label);
        let _ = writeln!(out, "series,x,y");
        for s in &self.series {
            for &(x, y) in &s.points {
                let _ = writeln!(out, "{},{x},{y}", s.label);
            }
        }
        out
    }

    /// Coarse ASCII rendering so figures are legible in a terminal log.
    pub fn to_ascii(&self) -> String {
        const W: usize = 60;
        const H: usize = 12;
        let all: Vec<(f64, f64)> =
            self.series.iter().flat_map(|s| s.points.iter().copied()).collect();
        if all.is_empty() {
            return format!("### {} (no data)\n", self.title);
        }
        let (mut x0, mut x1) = (f64::MAX, f64::MIN);
        let (mut y0, mut y1) = (f64::MAX, f64::MIN);
        for &(x, y) in &all {
            x0 = x0.min(x);
            x1 = x1.max(x);
            y0 = y0.min(y);
            y1 = y1.max(y);
        }
        if (x1 - x0).abs() < 1e-12 {
            x1 = x0 + 1.0;
        }
        if (y1 - y0).abs() < 1e-12 {
            y1 = y0 + 1.0;
        }
        let mut grid = vec![vec![b' '; W]; H];
        let marks = [b'*', b'o', b'+', b'x', b'#', b'@'];
        for (si, s) in self.series.iter().enumerate() {
            for &(x, y) in &s.points {
                let cx = ((x - x0) / (x1 - x0) * (W - 1) as f64).round() as usize;
                let cy = ((y - y0) / (y1 - y0) * (H - 1) as f64).round() as usize;
                grid[H - 1 - cy][cx] = marks[si % marks.len()];
            }
        }
        let mut out = String::new();
        let _ = writeln!(out, "### {}  [y: {} in {y0:.3}..{y1:.3}]", self.title, self.y_label);
        for row in grid {
            let _ = writeln!(out, "  |{}|", String::from_utf8_lossy(&row));
        }
        let _ = writeln!(out, "   x: {} in {x0:.3}..{x1:.3}", self.x_label);
        for (si, s) in self.series.iter().enumerate() {
            let _ = writeln!(out, "   {} = {}", marks[si % marks.len()] as char, s.label);
        }
        out
    }
}

/// Write a report file under `results/`, creating the directory.
pub fn save_report(name: &str, content: &str) -> anyhow::Result<std::path::PathBuf> {
    let dir = std::path::Path::new("results");
    std::fs::create_dir_all(dir)?;
    let path = dir.join(name);
    std::fs::write(&path, content)?;
    Ok(path)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_markdown_shape() {
        let mut t = Table::new("Table X", &["a", "bb"]);
        t.row(vec!["1".into(), "2".into()]);
        t.row(vec!["333".into(), "4".into()]);
        let md = t.to_markdown();
        assert!(md.contains("### Table X"));
        assert!(md.lines().filter(|l| l.starts_with('|')).count() == 4);
        // column alignment: all pipe rows same length
        let lens: Vec<usize> =
            md.lines().filter(|l| l.starts_with('|')).map(|l| l.len()).collect();
        assert!(lens.windows(2).all(|w| w[0] == w[1]));
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn table_arity_checked() {
        let mut t = Table::new("T", &["a", "b"]);
        t.row(vec!["only one".into()]);
    }

    #[test]
    fn figure_csv_and_ascii() {
        let mut f = Figure::new("Fig", "epoch", "mrr");
        f.add("p1", vec![(0.0, 0.1), (1.0, 0.2)]);
        f.add("p4", vec![(0.0, 0.15), (1.0, 0.3)]);
        let csv = f.to_csv();
        assert!(csv.contains("p1,0,0.1"));
        assert!(csv.contains("p4,1,0.3"));
        let ascii = f.to_ascii();
        assert!(ascii.contains("Fig"));
        assert!(ascii.contains('*') && ascii.contains('o'));
    }

    #[test]
    fn empty_figure_does_not_panic() {
        let f = Figure::new("E", "x", "y");
        assert!(f.to_ascii().contains("no data"));
    }
}

//! Overlapped evaluation: XLA scoring pipelined with host-side ranking.
//!
//! Mirrors the `train::pipeline` design. The PJRT runtime is not `Send`,
//! so artifact execution stays pinned to the coordinator thread; the
//! host-side half of eval — filtered-rank counting over `[q_pad, n_pad]`
//! score chunks — is plain data and moves onto the shared [`HostPool`].
//! While the coordinator executes the score artifact for chunk *s+1*,
//! pool threads rank chunk *s*, its queries striped across threads.
//!
//! Score readback rotates through `depth` (= `eval.prefetch_depth`)
//! slots, each owning one reusable `Vec<f32>` filled in place via
//! `literal_to_f32_into` — zero per-chunk heap allocation after warmup.
//! Before a slot is reused, the chunk previously occupying it is
//! *retired*: the coordinator waits for its stripe jobs (that wait is
//! the rank-stall time) and folds its ranks into the metrics in chunk
//! order, query order. Ranks are integers, so the fold is bit-identical
//! to the sequential `eval.host_threads = 0` reference no matter how
//! stripes interleave.
//!
//! Buffer-reclaim protocol: each stripe job drops its `Arc` clone of the
//! slot's score buffer *before* reporting done, so once the coordinator
//! has received every done message for the retiring chunk it holds the
//! only reference and `Arc::get_mut` must succeed.

use super::rank;
use super::{FilterIndex, Query, RankMetrics};
use crate::util::pool::HostPool;
use crate::util::timer::Stopwatch;
use anyhow::Result;
use std::sync::atomic::{AtomicU32, Ordering};
use std::sync::mpsc;
use std::sync::Arc;

/// One stripe job finished ranking its share of `chunk`.
struct StripeDone {
    chunk: usize,
    busy_secs: f64,
}

/// One rotating readback slot (see module docs).
struct Slot {
    /// Reused `[q_pad * n_pad]` score readback buffer.
    scores: Arc<Vec<f32>>,
    /// Per-query rank outputs; stripe `w` writes indices `w, w+s, ...`.
    ranks: Arc<Vec<AtomicU32>>,
    /// Live queries in the chunk currently occupying the slot.
    len: usize,
    /// Stripe jobs not yet reported done for the occupying chunk.
    pending: usize,
}

/// Coordinator-side state for the overlapped eval path.
///
/// Usage: one `submit_chunk` call per `[q_pad]` query chunk, passing a
/// `fill` closure that writes that chunk's scores into the slot buffer
/// (in production `literal_to_f32_into` from the score artifact; tests
/// and benches substitute synthetic scores), then one `finish` call to
/// drain. Metrics accumulate into the caller's [`RankMetrics`]; call
/// `RankMetrics::finalize` afterwards.
pub struct EvalPipeline<'a> {
    pool: &'a HostPool,
    queries: Arc<Vec<Query>>,
    filter: FilterIndex,
    n_pad: usize,
    n_ent: usize,
    depth: usize,
    slots: Vec<Slot>,
    done_tx: mpsc::Sender<StripeDone>,
    done_rx: mpsc::Receiver<StripeDone>,
    /// Next chunk index to fold into metrics (chunks retire in order).
    next_retire: usize,
    /// Chunks submitted so far; doubles as the next chunk index.
    submitted: usize,
    /// Total seconds pool threads spent ranking (summed across stripes).
    pub rank_busy_secs: f64,
    /// Seconds the coordinator spent blocked waiting on stripe jobs.
    pub stall_secs: f64,
}

impl<'a> EvalPipeline<'a> {
    pub fn new(
        pool: &'a HostPool,
        queries: Arc<Vec<Query>>,
        filter: FilterIndex,
        q_pad: usize,
        n_pad: usize,
        n_ent: usize,
        depth: usize,
    ) -> EvalPipeline<'a> {
        assert!(depth >= 1, "prefetch depth must be at least 1");
        assert!(n_ent <= n_pad, "entity count exceeds score row padding");
        let slots = (0..depth)
            .map(|_| Slot {
                scores: Arc::new(Vec::new()), // grown once by the first fill
                ranks: Arc::new((0..q_pad).map(|_| AtomicU32::new(0)).collect()),
                len: 0,
                pending: 0,
            })
            .collect();
        let (done_tx, done_rx) = mpsc::channel();
        EvalPipeline {
            pool,
            queries,
            filter,
            n_pad,
            n_ent,
            depth,
            slots,
            done_tx,
            done_rx,
            next_retire: 0,
            submitted: 0,
            rank_busy_secs: 0.0,
            stall_secs: 0.0,
        }
    }

    /// Score-and-rank one chunk: queries `[start, start + len)`.
    ///
    /// Retires the chunk previously occupying this slot (if any), fills
    /// the slot's score buffer via `fill`, and fans the chunk's rank
    /// work out across the pool. Returns without waiting for the rank
    /// jobs — the caller proceeds to execute the next chunk's scores.
    pub fn submit_chunk(
        &mut self,
        start: usize,
        len: usize,
        metrics: &mut RankMetrics,
        fill: impl FnOnce(&mut Vec<f32>) -> Result<()>,
    ) -> Result<()> {
        let chunk = self.submitted;
        while self.next_retire + self.depth <= chunk {
            self.retire_next(metrics);
        }
        let idx = chunk % self.depth;
        {
            let slot = &mut self.slots[idx];
            let buf = Arc::get_mut(&mut slot.scores)
                .expect("score buffer still shared after retire");
            fill(buf)?;
            anyhow::ensure!(
                buf.len() >= len * self.n_pad,
                "score chunk holds {} floats, need {}",
                buf.len(),
                len * self.n_pad
            );
            slot.len = len;
        }
        let stripes = self.pool.threads().min(len).max(1);
        self.slots[idx].pending = stripes;
        for w in 0..stripes {
            let scores = Arc::clone(&self.slots[idx].scores);
            let ranks = Arc::clone(&self.slots[idx].ranks);
            let queries = Arc::clone(&self.queries);
            let filter = self.filter.clone();
            let tx = self.done_tx.clone();
            let (n_pad, n_ent) = (self.n_pad, self.n_ent);
            self.pool.submit(move || {
                let sw = Stopwatch::new();
                for i in (w..len).step_by(stripes) {
                    let q = &queries[start + i];
                    let row = &scores[i * n_pad..i * n_pad + n_ent];
                    let known = if q.tail_dir {
                        filter.known_tails(q.anchor, q.r)
                    } else {
                        filter.known_heads(q.anchor, q.r)
                    };
                    let rank = rank::with_scratch(|scratch| {
                        rank::filtered_rank_sorting(row, q.truth, known, scratch)
                    });
                    ranks[i].store(rank as u32, Ordering::Relaxed);
                }
                let busy_secs = sw.elapsed_secs();
                // Release the buffer BEFORE reporting done — the
                // coordinator reclaims it with Arc::get_mut once the
                // last done message for this chunk arrives.
                drop(scores);
                let _ = tx.send(StripeDone { chunk, busy_secs });
            });
        }
        self.submitted += 1;
        Ok(())
    }

    /// Retire chunk `next_retire`: wait for its stripes, fold its ranks.
    fn retire_next(&mut self, metrics: &mut RankMetrics) {
        let idx = self.next_retire % self.depth;
        if self.slots[idx].pending > 0 {
            let sw = Stopwatch::new();
            while self.slots[idx].pending > 0 {
                let done = self.done_rx.recv().expect("rank stripe panicked");
                self.slots[done.chunk % self.depth].pending -= 1;
                self.rank_busy_secs += done.busy_secs;
            }
            self.stall_secs += sw.elapsed_secs();
        }
        // The channel recv synchronizes with each stripe's send, so the
        // Relaxed rank stores below are visible. Fold in query order:
        // identical accumulation order to the sequential reference.
        let slot = &self.slots[idx];
        for r in slot.ranks.iter().take(slot.len) {
            metrics.fold(r.load(Ordering::Relaxed) as usize);
        }
        self.next_retire += 1;
    }

    /// Drain every in-flight chunk into `metrics`.
    pub fn finish(&mut self, metrics: &mut RankMetrics) {
        while self.next_retire < self.submitted {
            self.retire_next(metrics);
        }
    }

    /// Fraction of pool ranking time hidden under coordinator execution
    /// (1.0 = fully overlapped), mirroring the trainer's definition.
    pub fn overlap_efficiency(&self) -> f64 {
        if self.rank_busy_secs <= 0.0 {
            return 1.0;
        }
        ((self.rank_busy_secs - self.stall_secs) / self.rank_busy_secs).clamp(0.0, 1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExperimentConfig;
    use crate::eval::build_queries;
    use crate::graph::generator;

    /// Deterministic synthetic score: coarse quantization produces many
    /// ties, exercising the strictly-better protocol under threading.
    fn synth_score(qi: usize, c: usize) -> f32 {
        ((qi.wrapping_mul(31) ^ c.wrapping_mul(17)) % 97) as f32 * 0.5 - 10.0
    }

    #[test]
    fn overlapped_fold_bit_identical_to_sequential() {
        let g = generator::generate(&ExperimentConfig::tiny().dataset);
        let filter = FilterIndex::build(&g).unwrap();
        let queries = Arc::new(build_queries(&g.test));
        let n_ent = g.num_entities;
        let (q_pad, n_pad) = (64, n_ent + 24);

        // Sequential reference: same kernel, same fold order.
        let mut want = RankMetrics::default();
        let mut scratch = Vec::new();
        let mut row = vec![0.0f32; n_ent];
        for (qi, q) in queries.iter().enumerate() {
            for (c, slot) in row.iter_mut().enumerate() {
                *slot = synth_score(qi, c);
            }
            let known = if q.tail_dir {
                filter.known_tails(q.anchor, q.r)
            } else {
                filter.known_heads(q.anchor, q.r)
            };
            want.fold(rank::filtered_rank_sorting(&row, q.truth, known, &mut scratch));
        }
        want.finalize();

        for (threads, depth) in [(1, 1), (2, 2), (4, 3)] {
            let pool = HostPool::new(threads);
            let mut pipe = EvalPipeline::new(
                &pool,
                Arc::clone(&queries),
                filter.clone(),
                q_pad,
                n_pad,
                n_ent,
                depth,
            );
            let mut got = RankMetrics::default();
            let mut buf_ptrs = std::collections::HashSet::new();
            let mut start = 0;
            while start < queries.len() {
                let len = q_pad.min(queries.len() - start);
                pipe.submit_chunk(start, len, &mut got, |buf| {
                    buf.resize(q_pad * n_pad, f32::NEG_INFINITY);
                    for i in 0..len {
                        for c in 0..n_ent {
                            buf[i * n_pad + c] = synth_score(start + i, c);
                        }
                    }
                    buf_ptrs.insert(buf.as_ptr() as usize);
                    Ok(())
                })
                .unwrap();
                start += len;
            }
            pipe.finish(&mut got);
            got.finalize();
            assert_eq!(got.num_queries, want.num_queries);
            assert_eq!(got.mrr.to_bits(), want.mrr.to_bits(), "threads={threads}");
            assert_eq!(got.hits1.to_bits(), want.hits1.to_bits());
            assert_eq!(got.hits3.to_bits(), want.hits3.to_bits());
            assert_eq!(got.hits10.to_bits(), want.hits10.to_bits());
            // Readback scratch rotates through at most `depth` buffers.
            assert!(
                buf_ptrs.len() <= depth,
                "expected <= {depth} score buffers, saw {}",
                buf_ptrs.len()
            );
        }
    }
}

//! Link-prediction evaluation (paper §4.2): filtered MRR and Hits@k.
//!
//! Protocol: encode the *full* train graph once (evaluation is a
//! single-node operation in the paper too — partitioning only affects
//! training), then for every test triple rank the true tail against all
//! entities under tail corruption and the true head under head
//! corruption, in the **filtered setting**: candidates that form a known
//! triple (train ∪ valid ∪ test) other than the probe itself are removed
//! from the ranking.
//!
//! The all-candidates scores come from the AOT `score` artifact
//! (`[Q, N] = (h[s] ∘ w[r]) · hᵀ`); DistMult's diagonal form makes head
//! corruption the same computation with the roles swapped.

use crate::graph::{KnowledgeGraph, Triple};
use crate::model::Manifest;
use crate::runtime::{literal_to_f32, HostTensor, Runtime};
use anyhow::{Context, Result};
use std::collections::HashMap;

/// MRR / Hits@k results (both-direction average, the standard protocol).
#[derive(Clone, Copy, Debug, Default)]
pub struct RankMetrics {
    pub mrr: f64,
    pub hits1: f64,
    pub hits3: f64,
    pub hits10: f64,
    pub num_queries: usize,
}

/// Filtered-setting index: (entity, relation) -> candidate entities that
/// form known triples. Built once per dataset; `tail[(s,r)]` lists t's,
/// `head[(t,r)]` lists s's.
pub struct FilterIndex {
    tail: HashMap<u64, Vec<u32>>,
    head: HashMap<u64, Vec<u32>>,
}

#[inline]
fn pack(a: u32, r: u32) -> u64 {
    ((a as u64) << 24) | r as u64
}

impl FilterIndex {
    pub fn build(g: &KnowledgeGraph) -> FilterIndex {
        let mut tail: HashMap<u64, Vec<u32>> = HashMap::new();
        let mut head: HashMap<u64, Vec<u32>> = HashMap::new();
        for e in g.train.iter().chain(&g.valid).chain(&g.test) {
            tail.entry(pack(e.s, e.r)).or_default().push(e.t);
            head.entry(pack(e.t, e.r)).or_default().push(e.s);
        }
        FilterIndex { tail, head }
    }

    fn known_tails(&self, s: u32, r: u32) -> &[u32] {
        self.tail.get(&pack(s, r)).map(Vec::as_slice).unwrap_or(&[])
    }

    fn known_heads(&self, t: u32, r: u32) -> &[u32] {
        self.head.get(&pack(t, r)).map(Vec::as_slice).unwrap_or(&[])
    }
}

/// Encode the full train graph with the `encode` artifact.
/// Returns h as a flat [N_pad * d] vector (N_pad from the manifest).
pub fn encode_full_graph(
    runtime: &Runtime,
    manifest: &Manifest,
    params: &[f32],
    graph: &KnowledgeGraph,
) -> Result<Vec<f32>> {
    let (file, n_pad, e_pad) = manifest.encode_entry()?;
    anyhow::ensure!(n_pad >= graph.num_entities, "encode bucket too small");
    let msgs = 2 * graph.train.len();
    anyhow::ensure!(e_pad >= msgs, "encode edge bucket too small ({e_pad} < {msgs})");
    let r = graph.num_relations as i32;

    // Identity node layout: cg-local id == global entity id.
    let mut src = Vec::with_capacity(e_pad);
    let mut dst = Vec::with_capacity(e_pad);
    let mut rel = Vec::with_capacity(e_pad);
    for e in &graph.train {
        src.push(e.s as i32);
        dst.push(e.t as i32);
        rel.push(e.r as i32);
        // inverse message
        src.push(e.t as i32);
        dst.push(e.s as i32);
        rel.push(e.r as i32 + r);
    }
    let mut emask = vec![1.0f32; msgs];
    src.resize(e_pad, 0);
    dst.resize(e_pad, 0);
    rel.resize(e_pad, 0);
    emask.resize(e_pad, 0.0);

    let exe = runtime.load(file)?;
    let node_input_feat;
    let node_input_ids;
    let node_input = if manifest.mode == "provided" {
        let f = manifest.feature_dim;
        let mut feats = vec![0f32; n_pad * f];
        feats[..graph.num_entities * f].copy_from_slice(&graph.features);
        node_input_feat = feats;
        HostTensor::F32(&node_input_feat, &[n_pad as i64, f as i64])
    } else {
        let mut ids: Vec<i32> = (0..graph.num_entities as i32).collect();
        ids.resize(n_pad, 0);
        node_input_ids = ids;
        HostTensor::I32(&node_input_ids, &[n_pad as i64])
    };
    let outputs = exe
        .run(&[
            HostTensor::F32(params, &[params.len() as i64]),
            node_input,
            HostTensor::I32(&src, &[e_pad as i64]),
            HostTensor::I32(&dst, &[e_pad as i64]),
            HostTensor::I32(&rel, &[e_pad as i64]),
            HostTensor::F32(&emask, &[e_pad as i64]),
        ])
        .context("running encode artifact")?;
    anyhow::ensure!(outputs.len() == 1, "encode returned {} outputs", outputs.len());
    literal_to_f32(&outputs[0])
}

/// Evaluate filtered MRR/Hits@k of `triples` given full-graph embeddings.
pub fn rank_triples(
    runtime: &Runtime,
    manifest: &Manifest,
    params: &[f32],
    h: &[f32],
    graph: &KnowledgeGraph,
    filter: &FilterIndex,
    triples: &[Triple],
) -> Result<RankMetrics> {
    let (file, q_pad, n_pad) = manifest.score_entry()?;
    let d = manifest.embed_dim;
    anyhow::ensure!(h.len() == n_pad * d, "embedding size mismatch");
    let exe = runtime.load(file)?;
    let rel_info = manifest.param("rel_dec")?;
    let rel_flat = &params[rel_info.offset..rel_info.offset + rel_info.size];
    let n_ent = graph.num_entities;

    // Queries: tail corruption uses (s, r) probing for t; head corruption
    // uses (t, r) probing for s (DistMult symmetry).
    struct Query {
        anchor: u32,
        r: u32,
        truth: u32,
        tail_dir: bool,
    }
    let mut queries = Vec::with_capacity(triples.len() * 2);
    for tr in triples {
        queries.push(Query { anchor: tr.s, r: tr.r, truth: tr.t, tail_dir: true });
        queries.push(Query { anchor: tr.t, r: tr.r, truth: tr.s, tail_dir: false });
    }

    let mut metrics = RankMetrics::default();
    let mut s_idx = vec![0i32; q_pad];
    let mut r_idx = vec![0i32; q_pad];
    for chunk in queries.chunks(q_pad) {
        for (i, q) in chunk.iter().enumerate() {
            s_idx[i] = q.anchor as i32;
            r_idx[i] = q.r as i32;
        }
        for i in chunk.len()..q_pad {
            s_idx[i] = 0;
            r_idx[i] = 0;
        }
        let outputs = exe.run(&[
            HostTensor::F32(h, &[n_pad as i64, d as i64]),
            HostTensor::F32(rel_flat, &[rel_flat.len() as i64]),
            HostTensor::I32(&s_idx, &[q_pad as i64]),
            HostTensor::I32(&r_idx, &[q_pad as i64]),
        ])?;
        let scores = literal_to_f32(&outputs[0])?; // [q_pad, n_pad]
        for (i, q) in chunk.iter().enumerate() {
            let row = &scores[i * n_pad..i * n_pad + n_ent];
            let truth_score = row[q.truth as usize];
            // Filtered rank: count strictly-better candidates, excluding
            // known positives and the padding region (already excluded by
            // slicing to n_ent).
            let known: &[u32] = if q.tail_dir {
                filter.known_tails(q.anchor, q.r)
            } else {
                filter.known_heads(q.anchor, q.r)
            };
            let mut better = 0usize;
            for (c, &sc) in row.iter().enumerate() {
                if sc > truth_score {
                    better += 1;
                }
                let _ = c;
            }
            // Remove known positives that outscored the truth.
            for &k in known {
                if k != q.truth && row[k as usize] > truth_score {
                    better -= 1;
                }
            }
            let rank = better + 1;
            metrics.mrr += 1.0 / rank as f64;
            metrics.hits1 += (rank <= 1) as usize as f64;
            metrics.hits3 += (rank <= 3) as usize as f64;
            metrics.hits10 += (rank <= 10) as usize as f64;
            metrics.num_queries += 1;
        }
    }
    let n = metrics.num_queries.max(1) as f64;
    metrics.mrr /= n;
    metrics.hits1 /= n;
    metrics.hits3 /= n;
    metrics.hits10 /= n;
    Ok(metrics)
}

/// Convenience: encode + rank in one call.
pub fn evaluate(
    runtime: &Runtime,
    manifest: &Manifest,
    params: &[f32],
    graph: &KnowledgeGraph,
    filter: &FilterIndex,
    triples: &[Triple],
) -> Result<RankMetrics> {
    let h = encode_full_graph(runtime, manifest, params, graph)?;
    rank_triples(runtime, manifest, params, &h, graph, filter, triples)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExperimentConfig;
    use crate::graph::generator;

    #[test]
    fn filter_index_lists_all_known() {
        let g = generator::generate(&ExperimentConfig::tiny().dataset);
        let idx = FilterIndex::build(&g);
        for e in g.train.iter().take(50) {
            assert!(idx.known_tails(e.s, e.r).contains(&e.t));
            assert!(idx.known_heads(e.t, e.r).contains(&e.s));
        }
        // A relation id beyond the graph has no entries.
        assert!(idx.known_tails(0, 999).is_empty());
    }

    #[test]
    fn metrics_are_probabilities() {
        // Pure-rust rank math smoke (runtime-dependent paths are covered
        // by integration tests): simulate by constructing metrics inline.
        let m = RankMetrics { mrr: 0.5, hits1: 0.3, hits3: 0.6, hits10: 0.9, num_queries: 10 };
        assert!(m.hits1 <= m.hits3 && m.hits3 <= m.hits10);
    }
}

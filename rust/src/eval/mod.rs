//! Link-prediction evaluation (paper §4.2): filtered MRR and Hits@k.
//!
//! Protocol: encode the *full* train graph once (evaluation is a
//! single-node operation in the paper too — partitioning only affects
//! training), then for every test triple rank the true tail against all
//! entities under tail corruption and the true head under head
//! corruption, in the **filtered setting**: candidates that form a known
//! triple (train ∪ valid ∪ test) other than the probe itself are removed
//! from the ranking.
//!
//! The all-candidates scores come from the AOT `score` artifact
//! (`[Q, N] = (h[s] ∘ w[r]) · hᵀ`); DistMult's diagonal form makes head
//! corruption the same computation with the roles swapped.
//!
//! Two execution paths produce bit-identical metrics:
//!
//! * **sequential** (`eval.host_threads = 0`): each score chunk is read
//!   back and ranked on the coordinator before the next chunk runs;
//! * **overlapped** (`eval.host_threads > 0`): [`pipeline::EvalPipeline`]
//!   ranks chunk *s* on a host pool while the coordinator executes the
//!   score artifact for chunk *s+1*, rotating `eval.prefetch_depth`
//!   readback buffers (zero per-chunk heap allocation).
//!
//! Both share the fused single-pass rank kernel in [`rank`] and fold
//! integer ranks in the same chunk-order, query-order sequence. Use
//! [`Evaluator`] for repeated evals — it caches the padded
//! [`EncodeInputs`] and owns the rank pool; per-eval timings
//! (`wall_secs`, `rank_stall_secs`, `overlap_efficiency`, ...) surface
//! as [`EvalStats`] in `EpochRecord` and the fig6b/fig7 tables.

pub mod pipeline;
pub mod rank;

use crate::config::EvalConfig;
use crate::graph::{KnowledgeGraph, Triple};
use crate::metrics::EvalStats;
use crate::model::Manifest;
use crate::runtime::{literal_to_f32_into, HostTensor, Runtime};
use crate::util::pool::HostPool;
use crate::util::timer::Stopwatch;
use anyhow::{Context, Result};
use std::collections::HashMap;
use std::sync::Arc;

pub use pipeline::EvalPipeline;
pub use rank::{filtered_rank, filtered_rank_sorting};

/// MRR / Hits@k results (both-direction average, the standard protocol).
#[derive(Clone, Copy, Debug, Default)]
pub struct RankMetrics {
    pub mrr: f64,
    pub hits1: f64,
    pub hits3: f64,
    pub hits10: f64,
    pub num_queries: usize,
}

impl RankMetrics {
    /// Accumulate one query's filtered rank. Ranks are integers, so any
    /// path that folds the same ranks in the same order produces
    /// bit-identical sums — the overlapped eval pipeline's invariant.
    #[inline]
    pub fn fold(&mut self, rank: usize) {
        self.mrr += 1.0 / rank as f64;
        self.hits1 += (rank <= 1) as usize as f64;
        self.hits3 += (rank <= 3) as usize as f64;
        self.hits10 += (rank <= 10) as usize as f64;
        self.num_queries += 1;
    }

    /// Turn accumulated sums into means (call once, after all folds).
    pub fn finalize(&mut self) {
        let n = self.num_queries.max(1) as f64;
        self.mrr /= n;
        self.hits1 /= n;
        self.hits3 /= n;
        self.hits10 /= n;
    }
}

/// One ranking probe: score `anchor` under relation `r` against every
/// entity and rank `truth`. Tail corruption probes `(s, r, ?)`; head
/// corruption probes `(?, r, t)` with the roles swapped (DistMult
/// symmetry makes both the same artifact call).
#[derive(Clone, Copy, Debug)]
pub struct Query {
    pub anchor: u32,
    pub r: u32,
    pub truth: u32,
    pub tail_dir: bool,
}

/// Expand triples into both-direction queries (tail probe then head
/// probe, in triple order). This ordering defines the metric
/// accumulation order that both eval paths share.
pub fn build_queries(triples: &[Triple]) -> Vec<Query> {
    let mut queries = Vec::with_capacity(triples.len() * 2);
    for tr in triples {
        queries.push(Query { anchor: tr.s, r: tr.r, truth: tr.t, tail_dir: true });
        queries.push(Query { anchor: tr.t, r: tr.r, truth: tr.s, tail_dir: false });
    }
    queries
}

struct FilterInner {
    tail: HashMap<u64, Vec<u32>>,
    head: HashMap<u64, Vec<u32>>,
}

/// Filtered-setting index: (entity, relation) -> candidate entities that
/// form known triples. Built once per dataset; `tail[(s,r)]` lists t's,
/// `head[(t,r)]` lists s's. The maps live behind an `Arc`, so cloning is
/// cheap and rank-pool jobs capture the index by value.
#[derive(Clone)]
pub struct FilterIndex {
    inner: Arc<FilterInner>,
}

/// Key layout: entity(32) | relation(32). Structurally collision-free
/// for u32 ids — the previous 24-bit shift silently collided once a
/// relation id (which includes inverse relations elsewhere in the
/// system) needed 24 bits or more.
#[inline]
fn pack(a: u32, r: u32) -> u64 {
    ((a as u64) << 32) | r as u64
}

impl FilterIndex {
    pub fn build(g: &KnowledgeGraph) -> Result<FilterIndex> {
        anyhow::ensure!(
            g.num_entities <= u32::MAX as usize && g.num_relations <= u32::MAX as usize,
            "FilterIndex packs (entity, relation) into a u64; ids must fit in 32 bits \
             (got {} entities, {} relations)",
            g.num_entities,
            g.num_relations
        );
        let mut tail: HashMap<u64, Vec<u32>> = HashMap::new();
        let mut head: HashMap<u64, Vec<u32>> = HashMap::new();
        for e in g.train.iter().chain(&g.valid).chain(&g.test) {
            tail.entry(pack(e.s, e.r)).or_default().push(e.t);
            head.entry(pack(e.t, e.r)).or_default().push(e.s);
        }
        Ok(FilterIndex { inner: Arc::new(FilterInner { tail, head }) })
    }

    pub fn known_tails(&self, s: u32, r: u32) -> &[u32] {
        self.inner.tail.get(&pack(s, r)).map(Vec::as_slice).unwrap_or(&[])
    }

    pub fn known_heads(&self, t: u32, r: u32) -> &[u32] {
        self.inner.head.get(&pack(t, r)).map(Vec::as_slice).unwrap_or(&[])
    }
}

/// Cached, padded inputs for the `encode` artifact.
///
/// The padded src/dst/rel/emask message buffers (`e_pad` entries each)
/// and the node input depend only on the graph and the manifest, not on
/// `params` — yet the old `encode_full_graph` re-materialized all of
/// them on every call, which `train.eval_every` turns into a per-epoch
/// cost. Build once, encode many times.
pub struct EncodeInputs {
    file: String,
    n_pad: usize,
    e_pad: usize,
    src: Vec<i32>,
    dst: Vec<i32>,
    rel: Vec<i32>,
    emask: Vec<f32>,
    /// Row-major `[n_pad, feature_dim]`; used in "provided" mode.
    node_feat: Vec<f32>,
    /// Identity node ids padded to `n_pad`; used in embedding mode.
    node_ids: Vec<i32>,
    provided: bool,
    feature_dim: usize,
}

impl EncodeInputs {
    pub fn build(manifest: &Manifest, graph: &KnowledgeGraph) -> Result<EncodeInputs> {
        let (file, n_pad, e_pad) = manifest.encode_entry()?;
        anyhow::ensure!(n_pad >= graph.num_entities, "encode bucket too small");
        let msgs = 2 * graph.train.len();
        anyhow::ensure!(e_pad >= msgs, "encode edge bucket too small ({e_pad} < {msgs})");
        let r = graph.num_relations as i32;

        // Identity node layout: cg-local id == global entity id.
        let mut src = Vec::with_capacity(e_pad);
        let mut dst = Vec::with_capacity(e_pad);
        let mut rel = Vec::with_capacity(e_pad);
        for e in &graph.train {
            src.push(e.s as i32);
            dst.push(e.t as i32);
            rel.push(e.r as i32);
            // inverse message
            src.push(e.t as i32);
            dst.push(e.s as i32);
            rel.push(e.r as i32 + r);
        }
        let mut emask = vec![1.0f32; msgs];
        src.resize(e_pad, 0);
        dst.resize(e_pad, 0);
        rel.resize(e_pad, 0);
        emask.resize(e_pad, 0.0);

        let provided = manifest.mode == "provided";
        let mut node_feat = Vec::new();
        let mut node_ids = Vec::new();
        if provided {
            let f = manifest.feature_dim;
            node_feat = vec![0f32; n_pad * f];
            node_feat[..graph.num_entities * f].copy_from_slice(&graph.features);
        } else {
            node_ids = (0..graph.num_entities as i32).collect();
            node_ids.resize(n_pad, 0);
        }
        Ok(EncodeInputs {
            file: file.to_string(),
            n_pad,
            e_pad,
            src,
            dst,
            rel,
            emask,
            node_feat,
            node_ids,
            provided,
            feature_dim: manifest.feature_dim,
        })
    }

    /// Run the encode artifact with these inputs and `params`, reading
    /// the `[n_pad * d]` embeddings into `out` (allocation reused).
    pub fn encode_into(&self, runtime: &Runtime, params: &[f32], out: &mut Vec<f32>) -> Result<()> {
        let exe = runtime.load(&self.file)?;
        let node_input = if self.provided {
            HostTensor::F32(&self.node_feat, &[self.n_pad as i64, self.feature_dim as i64])
        } else {
            HostTensor::I32(&self.node_ids, &[self.n_pad as i64])
        };
        let outputs = exe
            .run(&[
                HostTensor::F32(params, &[params.len() as i64]),
                node_input,
                HostTensor::I32(&self.src, &[self.e_pad as i64]),
                HostTensor::I32(&self.dst, &[self.e_pad as i64]),
                HostTensor::I32(&self.rel, &[self.e_pad as i64]),
                HostTensor::F32(&self.emask, &[self.e_pad as i64]),
            ])
            .context("running encode artifact")?;
        anyhow::ensure!(outputs.len() == 1, "encode returned {} outputs", outputs.len());
        literal_to_f32_into(&outputs[0], out)
    }
}

/// Encode the full train graph with the `encode` artifact.
/// Returns h as a flat [N_pad * d] vector (N_pad from the manifest).
///
/// One-shot convenience; repeated evals should hold an [`Evaluator`]
/// (or an [`EncodeInputs`]) so the padded buffers are built once.
pub fn encode_full_graph(
    runtime: &Runtime,
    manifest: &Manifest,
    params: &[f32],
    graph: &KnowledgeGraph,
) -> Result<Vec<f32>> {
    let inputs = EncodeInputs::build(manifest, graph)?;
    let mut h = Vec::new();
    inputs.encode_into(runtime, params, &mut h)?;
    Ok(h)
}

/// Score + rank `queries`: sequential when `pool` is `None`, overlapped
/// via [`EvalPipeline`] otherwise (`pool` carries the rank pool and the
/// prefetch depth). Shared by both public entry points so the two paths
/// cannot drift; see the module docs for the bit-identity argument.
#[allow(clippy::too_many_arguments)]
fn rank_queries(
    runtime: &Runtime,
    manifest: &Manifest,
    params: &[f32],
    h: &[f32],
    num_entities: usize,
    filter: &FilterIndex,
    queries: Arc<Vec<Query>>,
    pool: Option<(&HostPool, usize)>,
) -> Result<(RankMetrics, EvalStats)> {
    let (file, q_pad, n_pad) = manifest.score_entry()?;
    let d = manifest.embed_dim;
    anyhow::ensure!(h.len() == n_pad * d, "embedding size mismatch");
    anyhow::ensure!(num_entities <= n_pad, "score bucket smaller than entity count");
    let exe = runtime.load(file)?;
    let rel_info = manifest.param("rel_dec")?;
    let rel_flat = &params[rel_info.offset..rel_info.offset + rel_info.size];

    let mut metrics = RankMetrics::default();
    let mut stats = EvalStats::default();
    let mut pipe = pool.map(|(p, depth)| {
        let q = Arc::clone(&queries);
        EvalPipeline::new(p, q, filter.clone(), q_pad, n_pad, num_entities, depth)
    });
    let mut s_idx = vec![0i32; q_pad];
    let mut r_idx = vec![0i32; q_pad];
    // Sequential-path scratch, reused across chunks (zero per-chunk
    // allocation on this path too).
    let mut scores: Vec<f32> = Vec::new();
    let mut scratch: Vec<u32> = Vec::new();

    let mut start = 0;
    while start < queries.len() {
        let len = q_pad.min(queries.len() - start);
        for (i, q) in queries[start..start + len].iter().enumerate() {
            s_idx[i] = q.anchor as i32;
            r_idx[i] = q.r as i32;
        }
        for i in len..q_pad {
            s_idx[i] = 0;
            r_idx[i] = 0;
        }
        let sw = Stopwatch::new();
        let outputs = exe.run(&[
            HostTensor::F32(h, &[n_pad as i64, d as i64]),
            HostTensor::F32(rel_flat, &[rel_flat.len() as i64]),
            HostTensor::I32(&s_idx, &[q_pad as i64]),
            HostTensor::I32(&r_idx, &[q_pad as i64]),
        ])?;
        stats.score_secs += sw.elapsed_secs();
        match pipe.as_mut() {
            // Overlapped: hand the chunk to the pool and immediately
            // move on to execute the next chunk's scores.
            Some(p) => {
                p.submit_chunk(start, len, &mut metrics, |buf| {
                    literal_to_f32_into(&outputs[0], buf)
                })?;
            }
            // Sequential reference: rank on the coordinator now.
            None => {
                literal_to_f32_into(&outputs[0], &mut scores)?;
                let sw = Stopwatch::new();
                for (i, q) in queries[start..start + len].iter().enumerate() {
                    let row = &scores[i * n_pad..i * n_pad + num_entities];
                    let known = if q.tail_dir {
                        filter.known_tails(q.anchor, q.r)
                    } else {
                        filter.known_heads(q.anchor, q.r)
                    };
                    metrics.fold(rank::filtered_rank_sorting(row, q.truth, known, &mut scratch));
                }
                stats.rank_secs += sw.elapsed_secs();
            }
        }
        stats.num_chunks += 1;
        start += len;
    }
    if let Some(p) = pipe.as_mut() {
        p.finish(&mut metrics);
        stats.rank_secs = p.rank_busy_secs;
        stats.rank_stall_secs = p.stall_secs;
        stats.overlap_efficiency = p.overlap_efficiency();
    }
    metrics.finalize();
    Ok((metrics, stats))
}

/// Evaluate filtered MRR/Hits@k of `triples` given full-graph embeddings
/// (sequential path; the pipelined path lives behind [`Evaluator`]).
pub fn rank_triples(
    runtime: &Runtime,
    manifest: &Manifest,
    params: &[f32],
    h: &[f32],
    graph: &KnowledgeGraph,
    filter: &FilterIndex,
    triples: &[Triple],
) -> Result<RankMetrics> {
    let queries = Arc::new(build_queries(triples));
    let (metrics, _) =
        rank_queries(runtime, manifest, params, h, graph.num_entities, filter, queries, None)?;
    Ok(metrics)
}

/// Convenience: encode + rank in one call (sequential path).
pub fn evaluate(
    runtime: &Runtime,
    manifest: &Manifest,
    params: &[f32],
    graph: &KnowledgeGraph,
    filter: &FilterIndex,
    triples: &[Triple],
) -> Result<RankMetrics> {
    let h = encode_full_graph(runtime, manifest, params, graph)?;
    rank_triples(runtime, manifest, params, &h, graph, filter, triples)
}

/// Reusable evaluation driver: caches the padded [`EncodeInputs`], the
/// embedding readback buffer, and (with `eval.host_threads > 0`) the
/// rank host pool, so periodic evals inside a training run pay none of
/// that setup more than once.
pub struct Evaluator {
    inputs: EncodeInputs,
    /// Reused full-graph embedding readback buffer.
    h: Vec<f32>,
    pool: Option<HostPool>,
    depth: usize,
    num_entities: usize,
}

impl Evaluator {
    pub fn new(manifest: &Manifest, graph: &KnowledgeGraph, cfg: &EvalConfig) -> Result<Evaluator> {
        Ok(Evaluator {
            inputs: EncodeInputs::build(manifest, graph)?,
            h: Vec::new(),
            pool: if cfg.host_threads > 0 { Some(HostPool::new(cfg.host_threads)) } else { None },
            depth: cfg.prefetch_depth,
            num_entities: graph.num_entities,
        })
    }

    /// Encode the full graph under `params`, then score and rank
    /// `triples` (both directions, filtered setting). Returns metrics
    /// plus the timing breakdown surfaced in fig6b/fig7.
    pub fn evaluate(
        &mut self,
        runtime: &Runtime,
        manifest: &Manifest,
        params: &[f32],
        filter: &FilterIndex,
        triples: &[Triple],
    ) -> Result<(RankMetrics, EvalStats)> {
        let wall = Stopwatch::new();
        let sw = Stopwatch::new();
        self.inputs.encode_into(runtime, params, &mut self.h)?;
        let encode_secs = sw.elapsed_secs();
        let queries = Arc::new(build_queries(triples));
        let (metrics, mut stats) = rank_queries(
            runtime,
            manifest,
            params,
            &self.h,
            self.num_entities,
            filter,
            queries,
            self.pool.as_ref().map(|p| (p, self.depth)),
        )?;
        stats.encode_secs = encode_secs;
        stats.wall_secs = wall.elapsed_secs();
        Ok((metrics, stats))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExperimentConfig;
    use crate::graph::generator;

    #[test]
    fn filter_index_lists_all_known() {
        let g = generator::generate(&ExperimentConfig::tiny().dataset);
        let idx = FilterIndex::build(&g).unwrap();
        for e in g.train.iter().take(50) {
            assert!(idx.known_tails(e.s, e.r).contains(&e.t));
            assert!(idx.known_heads(e.t, e.r).contains(&e.s));
        }
        // A relation id beyond the graph has no entries.
        assert!(idx.known_tails(0, 999).is_empty());
        // Clones share the same inner maps.
        let c = idx.clone();
        assert!(std::ptr::eq(c.known_tails(0, 0).as_ptr(), idx.known_tails(0, 0).as_ptr()));
    }

    #[test]
    fn build_queries_orders_tail_then_head() {
        let triples = [Triple::new(1, 0, 2), Triple::new(3, 1, 4)];
        let qs = build_queries(&triples);
        assert_eq!(qs.len(), 4);
        assert!(qs[0].tail_dir && qs[0].anchor == 1 && qs[0].truth == 2);
        assert!(!qs[1].tail_dir && qs[1].anchor == 2 && qs[1].truth == 1);
        assert!(qs[2].tail_dir && qs[2].anchor == 3 && qs[2].truth == 4);
    }

    #[test]
    fn rank_metrics_fold_matches_direct_means() {
        let mut m = RankMetrics::default();
        for rank in [1usize, 2, 3, 10, 11] {
            m.fold(rank);
        }
        m.finalize();
        assert_eq!(m.num_queries, 5);
        let want_mrr = (1.0 + 0.5 + 1.0 / 3.0 + 0.1 + 1.0 / 11.0) / 5.0;
        assert!((m.mrr - want_mrr).abs() < 1e-15);
        assert!((m.hits1 - 0.2).abs() < 1e-15);
        assert!((m.hits3 - 0.6).abs() < 1e-15);
        assert!((m.hits10 - 0.8).abs() < 1e-15);
        assert!(m.hits1 <= m.hits3 && m.hits3 <= m.hits10);
    }

    #[test]
    fn encode_inputs_cache_padded_buffers() {
        let m = Manifest::parse(crate::model::manifest::tests::SAMPLE).unwrap();
        let g = generator::generate(&ExperimentConfig::tiny().dataset);
        let inputs = EncodeInputs::build(&m, &g).unwrap();
        let (_, n_pad, e_pad) = m.encode_entry().unwrap();
        assert_eq!(inputs.src.len(), e_pad);
        assert_eq!(inputs.dst.len(), e_pad);
        assert_eq!(inputs.rel.len(), e_pad);
        assert_eq!(inputs.emask.len(), e_pad);
        // One forward + one inverse message per train edge, then padding.
        let live: f64 = inputs.emask.iter().map(|&v| v as f64).sum();
        assert_eq!(live as usize, 2 * g.train.len());
        // Inverse messages shift the relation id by num_relations.
        assert_eq!(inputs.rel[1], inputs.rel[0] + g.num_relations as i32);
        assert_eq!((inputs.src[0], inputs.dst[0]), (inputs.dst[1], inputs.src[1]));
        // Embedding mode: identity node ids padded to n_pad.
        assert!(!inputs.provided);
        assert_eq!(inputs.node_ids.len(), n_pad);
        assert_eq!(inputs.node_ids[5], 5);
        assert_eq!(inputs.node_ids[n_pad - 1], 0);
    }
}

//! Pure filtered-rank kernel, independent of any runtime.
//!
//! The old `rank_triples` loop made two passes per query: count every
//! candidate strictly above the truth score, then subtract the known
//! positives that outscored it. This module fuses the two into a single
//! pass with a merge pointer into a *sorted* known-candidate list: known
//! candidates (other than the truth itself) are skipped instead of
//! counted-then-subtracted. Besides touching each score exactly once,
//! the fused form is robust to duplicate entries in the known list —
//! the old subtract pass would discount a duplicate twice (and could
//! underflow), while skipping naturally deduplicates.
//!
//! Ranks are plain integers, so they are exact: any schedule that
//! computes per-query ranks and folds them into [`RankMetrics`] in the
//! same query order is bit-identical to the sequential reference. This
//! is the property the overlapped eval pipeline relies on.
//!
//! [`RankMetrics`]: super::RankMetrics

use std::cell::RefCell;

/// Filtered rank of `truth` within `row` (scores for candidates
/// `0..row.len()`), with known positives removed from the ranking.
///
/// `known_sorted` must be sorted ascending (duplicates allowed). The
/// rank is `1 + |{c : row[c] > row[truth], c not known-or-c == truth}|`
/// — strictly-better counting, so ties with the truth score do not hurt
/// the rank (the standard optimistic filtered protocol, matching the
/// previous implementation bit for bit).
pub fn filtered_rank(row: &[f32], truth: u32, known_sorted: &[u32]) -> usize {
    debug_assert!(
        known_sorted.windows(2).all(|w| w[0] <= w[1]),
        "known candidates must be sorted"
    );
    let truth_score = row[truth as usize];
    let mut better = 0usize;
    let mut k = 0usize;
    for (c, &sc) in row.iter().enumerate() {
        let c = c as u32;
        while k < known_sorted.len() && known_sorted[k] < c {
            k += 1;
        }
        if k < known_sorted.len() && known_sorted[k] == c && c != truth {
            continue; // known positive: filtered out of the ranking
        }
        if sc > truth_score {
            better += 1;
        }
    }
    better + 1
}

/// [`filtered_rank`] for an *unsorted* known list, sorting into a
/// caller-provided scratch buffer so repeated calls allocate nothing
/// once the scratch has grown to the largest known-list size.
pub fn filtered_rank_sorting(
    row: &[f32],
    truth: u32,
    known: &[u32],
    scratch: &mut Vec<u32>,
) -> usize {
    scratch.clear();
    scratch.extend_from_slice(known);
    scratch.sort_unstable();
    filtered_rank(row, truth, scratch)
}

thread_local! {
    static SCRATCH: RefCell<Vec<u32>> = const { RefCell::new(Vec::new()) };
}

/// Run `f` with this thread's persistent rank scratch buffer. Pool
/// threads use this so each keeps one long-lived sort buffer instead of
/// allocating per query.
pub fn with_scratch<R>(f: impl FnOnce(&mut Vec<u32>) -> R) -> R {
    SCRATCH.with(|s| f(&mut s.borrow_mut()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn truth_is_best_ranks_first() {
        let row = [0.1, 0.9, 0.3, 0.2];
        assert_eq!(filtered_rank(&row, 1, &[]), 1);
    }

    #[test]
    fn ties_do_not_count_against_the_truth() {
        // Strictly-better counting: equal scores leave the rank alone.
        let row = [2.0, 2.0, 2.0, 3.0];
        assert_eq!(filtered_rank(&row, 0, &[]), 2); // only 3.0 beats it
    }

    #[test]
    fn known_positives_that_outrank_are_filtered() {
        let row = [0.5, 0.9, 0.8, 0.1];
        // Unfiltered, two candidates beat truth=3; both are known.
        assert_eq!(filtered_rank(&row, 3, &[1, 2]), 2); // 0.5 still beats 0.1
        assert_eq!(filtered_rank(&row, 3, &[0, 1, 2]), 1); // all outrankers known
    }

    #[test]
    fn truth_in_known_list_does_not_filter_itself() {
        let row = [0.5, 0.9, 0.8, 0.1];
        assert_eq!(filtered_rank(&row, 1, &[1]), 1);
        assert_eq!(filtered_rank(&row, 2, &[1, 2]), 1); // 0.9 filtered, truth kept
    }

    #[test]
    fn duplicate_known_entries_filter_once() {
        let row = [0.5, 0.9, 0.8, 0.1];
        // The old two-pass kernel would subtract candidate 1 twice here.
        assert_eq!(filtered_rank(&row, 3, &[1, 1, 1, 2]), 2);
    }

    #[test]
    fn sorting_wrapper_matches_presorted() {
        let mut rng = Rng::seeded(0x8a11);
        let mut scratch = Vec::new();
        for _ in 0..200 {
            let n = 1 + rng.below(64);
            let row: Vec<f32> = (0..n).map(|_| rng.uniform_f32(-1.0, 1.0)).collect();
            let truth = rng.below(n) as u32;
            let known: Vec<u32> = (0..rng.below(16)).map(|_| rng.below(n) as u32).collect();
            let mut sorted = known.clone();
            sorted.sort_unstable();
            assert_eq!(
                filtered_rank_sorting(&row, truth, &known, &mut scratch),
                filtered_rank(&row, truth, &sorted),
            );
        }
    }

    #[test]
    fn fused_matches_two_pass_reference() {
        // Reference: the pre-fusion algorithm (with deduped knowns so
        // both sides agree; the fused kernel dedups by construction).
        fn two_pass(row: &[f32], truth: u32, known: &[u32]) -> usize {
            let truth_score = row[truth as usize];
            let mut better = row.iter().filter(|&&sc| sc > truth_score).count();
            for &k in known {
                if k != truth && row[k as usize] > truth_score {
                    better -= 1;
                }
            }
            better + 1
        }
        let mut rng = Rng::seeded(0xfade);
        for _ in 0..500 {
            let n = 1 + rng.below(128);
            let row: Vec<f32> = (0..n).map(|_| rng.uniform_f32(-2.0, 2.0)).collect();
            let truth = rng.below(n) as u32;
            let mut known: Vec<u32> = (0..rng.below(20)).map(|_| rng.below(n) as u32).collect();
            known.sort_unstable();
            known.dedup();
            assert_eq!(filtered_rank(&row, truth, &known), two_pass(&row, truth, &known));
        }
    }
}

//! Model-side glue on the Rust side: the artifact manifest (the L2⇄L3
//! contract written by `python/compile/aot.py`), flat-parameter
//! initialization matching the manifest's init specs, and size-bucket
//! selection for padded entry points.

pub mod manifest;
pub mod params;

pub use manifest::{EmbeddingSegment, EntryInfo, Manifest, ParamInfo};
pub use params::init_params;

//! Flat parameter vector: initialization from the manifest's init specs.
//!
//! The distribution family matches `python/compile/model.py::init_params`
//! (Xavier/Glorot uniform with limit sqrt(6/(fan_in+fan_out)), zeros for
//! biases) but uses this crate's deterministic RNG — the Python and Rust
//! initializers produce *different draws* from the *same distribution*,
//! which is all replication needs. All replicas start from the leader's
//! vector, so distributed training sees one consistent init.

use super::manifest::Manifest;
use crate::util::rng::Rng;

/// Initialize the flat parameter vector per the manifest.
pub fn init_params(manifest: &Manifest, seed: u64) -> Vec<f32> {
    let mut flat = vec![0f32; manifest.param_count];
    let mut rng = Rng::seeded(seed ^ 0x9A7A_11E1);
    for p in &manifest.params {
        let slice = &mut flat[p.offset..p.offset + p.size];
        match p.init.as_str() {
            "zeros" => slice.fill(0.0),
            "xavier_uniform" => {
                let limit = (6.0 / (p.fan_in + p.fan_out) as f64).sqrt() as f32;
                for v in slice.iter_mut() {
                    *v = rng.uniform_f32(-limit, limit);
                }
            }
            other => {
                // Unknown init kinds fall back to a small uniform so a
                // newer manifest degrades gracefully; loud in the log.
                crate::log_warn!("unknown init {other:?} for param {} — using ±0.05", p.name);
                for v in slice.iter_mut() {
                    *v = rng.uniform_f32(-0.05, 0.05);
                }
            }
        }
    }
    flat
}

/// View one named parameter inside the flat vector.
pub fn param_slice<'a>(manifest: &Manifest, flat: &'a [f32], name: &str) -> anyhow::Result<&'a [f32]> {
    let p = manifest.param(name)?;
    Ok(&flat[p.offset..p.offset + p.size])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::manifest::tests::SAMPLE;

    fn manifest() -> Manifest {
        Manifest::parse(SAMPLE).unwrap()
    }

    #[test]
    fn init_respects_layout_and_kinds() {
        let m = manifest();
        let flat = init_params(&m, 1);
        assert_eq!(flat.len(), m.param_count);
        // bias_0 is zeros
        let bias = param_slice(&m, &flat, "bias_0").unwrap();
        assert!(bias.iter().all(|&x| x == 0.0));
        // ent_emb is xavier with limit sqrt(6/32) ≈ 0.433
        let emb = param_slice(&m, &flat, "ent_emb").unwrap();
        let limit = (6.0f32 / 32.0).sqrt();
        assert!(emb.iter().all(|&x| x.abs() <= limit));
        assert!(emb.iter().any(|&x| x != 0.0));
    }

    #[test]
    fn init_is_deterministic_and_seed_sensitive() {
        let m = manifest();
        assert_eq!(init_params(&m, 7), init_params(&m, 7));
        assert_ne!(init_params(&m, 7), init_params(&m, 8));
    }

    #[test]
    fn xavier_draws_fill_the_range() {
        let m = manifest();
        let flat = init_params(&m, 3);
        let emb = param_slice(&m, &flat, "ent_emb").unwrap();
        let limit = (6.0f32 / 32.0).sqrt();
        let max = emb.iter().cloned().fold(0f32, |a, b| a.max(b.abs()));
        assert!(max > limit * 0.5, "draws suspiciously concentrated: max |x| = {max}");
    }
}

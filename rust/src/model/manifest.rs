//! `artifacts/<cfg>/manifest.json` parsing — the single source of truth
//! for what was AOT-compiled: model hyperparameters, the flat parameter
//! layout (name/shape/offset/init), and every lowered entry point with
//! its padded shapes.

use crate::util::json;
use anyhow::{Context, Result};
use std::path::Path;

#[derive(Clone, Debug)]
pub struct ParamInfo {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
    pub size: usize,
    /// "xavier_uniform" | "zeros"
    pub init: String,
    pub fan_in: usize,
    pub fan_out: usize,
}

/// Resolved location of the entity-embedding table inside the flat
/// parameter vector — the key the row-sparse gradient path is built on
/// (see `train::sparse`). `rows` is the *padded* table height from the
/// manifest (≥ the dataset's entity count), `dim` the embedding width.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct EmbeddingSegment {
    /// First flat index of the table.
    pub offset: usize,
    /// Number of embedding rows.
    pub rows: usize,
    /// Floats per row.
    pub dim: usize,
}

impl EmbeddingSegment {
    /// Total floats in the segment.
    pub fn len(&self) -> usize {
        self.rows * self.dim
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// One-past-the-end flat index.
    pub fn end(&self) -> usize {
        self.offset + self.len()
    }
}

#[derive(Clone, Debug)]
pub enum EntryInfo {
    TrainStep { file: String, nodes: usize, edges: usize, triples: usize },
    Encode { file: String, nodes: usize, edges: usize },
    Score { file: String, queries: usize, nodes: usize },
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub name: String,
    /// "embedding" | "provided"
    pub mode: String,
    pub entities: usize,
    pub relations: usize,
    pub embed_dim: usize,
    pub num_bases: usize,
    pub num_layers: usize,
    pub feature_dim: usize,
    pub dropout: f64,
    pub param_count: usize,
    pub params: Vec<ParamInfo>,
    pub entries: Vec<EntryInfo>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts`"))?;
        Self::parse(&text).with_context(|| format!("parsing {path:?}"))
    }

    pub fn parse(text: &str) -> Result<Manifest> {
        let j = json::parse(text)?;
        let version = j.req_usize("version")?;
        anyhow::ensure!(version == 1, "unsupported manifest version {version}");
        let model = j.req("model")?;
        let mut params = Vec::new();
        for p in j.req_arr("params")? {
            params.push(ParamInfo {
                name: p.req_str("name")?.to_string(),
                shape: p
                    .req_arr("shape")?
                    .iter()
                    .map(|x| x.as_usize().context("bad shape element"))
                    .collect::<Result<_>>()?,
                offset: p.req_usize("offset")?,
                size: p.req_usize("size")?,
                init: p.req_str("init")?.to_string(),
                fan_in: p.req_usize("fan_in")?,
                fan_out: p.req_usize("fan_out")?,
            });
        }
        let mut entries = Vec::new();
        for e in j.req_arr("entries")? {
            let file = e.req_str("file")?.to_string();
            match e.req_str("kind")? {
                "train_step" => entries.push(EntryInfo::TrainStep {
                    file,
                    nodes: e.req_usize("nodes")?,
                    edges: e.req_usize("edges")?,
                    triples: e.req_usize("triples")?,
                }),
                "encode" => entries.push(EntryInfo::Encode {
                    file,
                    nodes: e.req_usize("nodes")?,
                    edges: e.req_usize("edges")?,
                }),
                "score" => entries.push(EntryInfo::Score {
                    file,
                    queries: e.req_usize("queries")?,
                    nodes: e.req_usize("nodes")?,
                }),
                other => anyhow::bail!("unknown entry kind {other:?}"),
            }
        }
        let m = Manifest {
            name: j.req_str("name")?.to_string(),
            mode: j.req_str("mode")?.to_string(),
            entities: model.req_usize("entities")?,
            relations: model.req_usize("relations")?,
            embed_dim: model.req_usize("embed_dim")?,
            num_bases: model.req_usize("num_bases")?,
            num_layers: model.req_usize("num_layers")?,
            feature_dim: model.req_usize("feature_dim")?,
            dropout: model.req("dropout")?.as_f64().context("dropout")?,
            param_count: j.req_usize("param_count")?,
            params,
            entries,
        };
        m.check()?;
        Ok(m)
    }

    /// Layout sanity: params must exactly tile [0, param_count).
    pub fn check(&self) -> Result<()> {
        let mut off = 0;
        for p in &self.params {
            anyhow::ensure!(
                p.offset == off,
                "param {} at offset {} (expected {off})",
                p.name,
                p.offset
            );
            let numel: usize = p.shape.iter().product();
            anyhow::ensure!(numel == p.size, "param {} size mismatch", p.name);
            off += p.size;
        }
        anyhow::ensure!(
            off == self.param_count,
            "params tile {off} floats but param_count is {}",
            self.param_count
        );
        anyhow::ensure!(
            matches!(self.mode.as_str(), "embedding" | "provided"),
            "bad mode {}",
            self.mode
        );
        Ok(())
    }

    /// Smallest train_step bucket fitting (nodes, edges, triples); cost
    /// model = padded edge count (the step's dominant term), then triples.
    pub fn pick_train_bucket(
        &self,
        nodes: usize,
        edges: usize,
        triples: usize,
    ) -> Option<&EntryInfo> {
        self.entries
            .iter()
            .filter(|e| match e {
                EntryInfo::TrainStep { nodes: n, edges: ee, triples: b, .. } => {
                    *n >= nodes && *ee >= edges && *b >= triples
                }
                _ => false,
            })
            .min_by_key(|e| match e {
                EntryInfo::TrainStep { edges, triples, .. } => (*edges, *triples),
                _ => unreachable!(),
            })
    }

    pub fn encode_entry(&self) -> Result<(&str, usize, usize)> {
        for e in &self.entries {
            if let EntryInfo::Encode { file, nodes, edges } = e {
                return Ok((file, *nodes, *edges));
            }
        }
        anyhow::bail!("manifest has no encode entry")
    }

    pub fn score_entry(&self) -> Result<(&str, usize, usize)> {
        for e in &self.entries {
            if let EntryInfo::Score { file, queries, nodes } = e {
                return Ok((file, *queries, *nodes));
            }
        }
        anyhow::bail!("manifest has no score entry")
    }

    /// Resolve the `ent_emb` segment from the param layout, if present.
    /// Returns `None` in "provided"-features mode (no trainable embedding
    /// table) — callers then treat the whole vector as the dense tail.
    pub fn embedding_segment(&self) -> Option<EmbeddingSegment> {
        let p = self.params.iter().find(|p| p.name == "ent_emb")?;
        if p.shape.len() != 2 {
            return None;
        }
        Some(EmbeddingSegment { offset: p.offset, rows: p.shape[0], dim: p.shape[1] })
    }

    /// Resolve the `rel_dec` (relation-decoder) table, if it is a 2-D
    /// `[relations, dim]` parameter. The decoder gathers one row per
    /// triple, so its gradient is row-sparse in the batch's relation ids
    /// — `train::sparse` exploits this alongside the entity table.
    /// Returns `None` for manifests whose `rel_dec` is not 2-D.
    pub fn relation_segment(&self) -> Option<EmbeddingSegment> {
        let p = self.params.iter().find(|p| p.name == "rel_dec")?;
        if p.shape.len() != 2 {
            return None;
        }
        Some(EmbeddingSegment { offset: p.offset, rows: p.shape[0], dim: p.shape[1] })
    }

    pub fn param(&self, name: &str) -> Result<&ParamInfo> {
        self.params
            .iter()
            .find(|p| p.name == name)
            .with_context(|| format!("manifest has no param {name:?}"))
    }
}

#[cfg(test)]
pub(crate) mod tests {
    use super::*;

    pub(crate) const SAMPLE: &str = r#"{
      "version": 1, "name": "tiny", "mode": "embedding",
      "model": {"entities": 300, "relations": 8, "embed_dim": 16,
                "num_bases": 2, "num_layers": 2, "feature_dim": 0,
                "dropout": 0.0},
      "param_count": 152,
      "params": [
        {"name": "ent_emb", "shape": [8, 16], "offset": 0, "size": 128,
         "init": "xavier_uniform", "fan_in": 16, "fan_out": 16},
        {"name": "bias_0", "shape": [16], "offset": 128, "size": 16,
         "init": "zeros", "fan_in": 16, "fan_out": 16},
        {"name": "rel_dec", "shape": [8], "offset": 144, "size": 8,
         "init": "xavier_uniform", "fan_in": 4, "fan_out": 4}
      ],
      "entries": [
        {"kind": "train_step", "file": "a.hlo.txt", "nodes": 320,
         "edges": 8192, "triples": 8192},
        {"kind": "train_step", "file": "b.hlo.txt", "nodes": 320,
         "edges": 4096, "triples": 2048},
        {"kind": "encode", "file": "e.hlo.txt", "nodes": 320, "edges": 8192},
        {"kind": "score", "file": "s.hlo.txt", "queries": 256, "nodes": 320}
      ]
    }"#;

    #[test]
    fn parses_and_checks_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.name, "tiny");
        assert_eq!(m.params.len(), 3);
        assert_eq!(m.entries.len(), 4);
        assert_eq!(m.param("bias_0").unwrap().init, "zeros");
        assert!(m.param("nope").is_err());
    }

    #[test]
    fn bucket_selection_prefers_smallest_fit() {
        let m = Manifest::parse(SAMPLE).unwrap();
        match m.pick_train_bucket(100, 3000, 1000).unwrap() {
            EntryInfo::TrainStep { file, .. } => assert_eq!(file, "b.hlo.txt"),
            _ => panic!(),
        }
        match m.pick_train_bucket(100, 5000, 1000).unwrap() {
            EntryInfo::TrainStep { file, .. } => assert_eq!(file, "a.hlo.txt"),
            _ => panic!(),
        }
        assert!(m.pick_train_bucket(100, 9000, 1000).is_none());
        assert!(m.pick_train_bucket(400, 100, 100).is_none());
    }

    #[test]
    fn layout_gaps_are_rejected() {
        let broken = SAMPLE.replace("\"offset\": 128", "\"offset\": 130");
        assert!(Manifest::parse(&broken).is_err());
    }

    #[test]
    fn bad_version_rejected() {
        let broken = SAMPLE.replace("\"version\": 1", "\"version\": 99");
        assert!(Manifest::parse(&broken).is_err());
    }

    #[test]
    fn embedding_segment_resolves_from_layout() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let seg = m.embedding_segment().unwrap();
        assert_eq!(seg, EmbeddingSegment { offset: 0, rows: 8, dim: 16 });
        assert_eq!(seg.len(), 128);
        assert_eq!(seg.end(), 128);
        // Without an ent_emb param (provided-features mode) there is no
        // segment.
        let provided = SAMPLE.replace("\"name\": \"ent_emb\"", "\"name\": \"w_in\"");
        let m2 = Manifest::parse(&provided).unwrap();
        assert!(m2.embedding_segment().is_none());
    }

    #[test]
    fn relation_segment_requires_2d_rel_dec() {
        // SAMPLE's rel_dec is 1-D (a [8] vector): no row structure to
        // exploit, so no segment.
        let m = Manifest::parse(SAMPLE).unwrap();
        assert!(m.relation_segment().is_none());
        // A 2-D [4, 2] rel_dec of the same size resolves.
        let two_d = SAMPLE.replace(
            "\"name\": \"rel_dec\", \"shape\": [8]",
            "\"name\": \"rel_dec\", \"shape\": [4, 2]",
        );
        let m2 = Manifest::parse(&two_d).unwrap();
        let seg = m2.relation_segment().unwrap();
        assert_eq!(seg, EmbeddingSegment { offset: 144, rows: 4, dim: 2 });
        assert_eq!(seg.end(), 152);
    }

    #[test]
    fn encode_and_score_lookup() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let (f, n, e) = m.encode_entry().unwrap();
        assert_eq!((f, n, e), ("e.hlo.txt", 320, 8192));
        let (f, q, n) = m.score_entry().unwrap();
        assert_eq!((f, q, n), ("s.hlo.txt", 256, 320));
    }
}

//! Mini property-testing harness (`proptest` is unavailable offline).
//!
//! [`prop_check`] runs a property over `cases` seeded random inputs; on
//! failure it reports the failing seed so the case can be replayed
//! exactly (`KGSCALE_PROP_SEED=<seed>` reruns only that seed). No
//! shrinking — generators here are parameterized small enough that raw
//! failing cases are readable.

use crate::util::rng::Rng;

/// Run `property(rng)` for `cases` independent seeds derived from `base`.
/// Panics with the failing seed on the first violation.
pub fn prop_check(name: &str, base: u64, cases: usize, mut property: impl FnMut(&mut Rng)) {
    if let Ok(seed) = std::env::var("KGSCALE_PROP_SEED") {
        let seed: u64 = seed.parse().expect("KGSCALE_PROP_SEED must be a u64");
        let mut rng = Rng::seeded(seed);
        property(&mut rng);
        return;
    }
    for case in 0..cases {
        let seed = base
            .wrapping_mul(0x9E3779B97F4A7C15)
            .wrapping_add(case as u64);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut rng = Rng::seeded(seed);
            property(&mut rng);
        }));
        if let Err(e) = result {
            eprintln!(
                "property {name:?} failed on case {case} — replay with KGSCALE_PROP_SEED={seed}"
            );
            std::panic::resume_unwind(e);
        }
    }
}

/// Generators for property tests.
pub mod gen {
    use crate::config::DatasetConfig;
    use crate::graph::{generator, KnowledgeGraph};
    use crate::util::rng::Rng;

    /// A random small KG: 50-400 entities, 2-12 relations, density 2-8.
    pub fn small_kg(rng: &mut Rng) -> KnowledgeGraph {
        let entities = 50 + rng.below(350);
        let relations = 2 + rng.below(10);
        let avg_deg = 2 + rng.below(6);
        let train_edges = entities * avg_deg;
        let cfg = DatasetConfig {
            name: "prop".into(),
            kind: crate::config::DatasetKind::ZipfKg,
            entities,
            relations,
            train_edges,
            valid_edges: (train_edges / 20).max(1),
            test_edges: (train_edges / 20).max(1),
            feature_dim: 0,
            zipf_exponent: 1.0 + rng.next_f64() * 0.5,
            seed: rng.next_u64(),
        };
        generator::generate(&cfg)
    }

    /// Random partition count in 1..=8.
    pub fn partitions(rng: &mut Rng) -> usize {
        1 + rng.below(8)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prop_check_passes_quiet() {
        prop_check("trivial", 1, 5, |rng| {
            let x = rng.below(100);
            assert!(x < 100);
        });
    }

    #[test]
    #[should_panic]
    fn prop_check_reports_failure() {
        prop_check("failing", 2, 10, |rng| {
            assert!(rng.below(10) < 9, "intentional");
        });
    }

    #[test]
    fn generators_produce_valid_graphs() {
        prop_check("gen-valid", 3, 3, |rng| {
            let g = gen::small_kg(rng);
            g.check().unwrap();
            assert!(g.num_entities >= 50);
        });
    }
}

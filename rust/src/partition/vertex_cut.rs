//! Streaming vertex-cut edge partitioners.
//!
//! Vertex-cut partitioning assigns each *edge* to exactly one partition;
//! vertices incident to edges in several partitions are *replicated*
//! (paper §3.2.1: "divides the edges into disjoint partitions and
//! produces balanced partitions by minimizing the vertex replication").
//!
//! Two algorithms:
//!
//! * **HDRF** (High-Degree Replicated First; Petroni et al., CIKM'15) —
//!   the replication-minimizing, balance-aware greedy streaming
//!   partitioner. This is our stand-in for the paper's KaHIP edge
//!   partitioning: same objective (minimize replication factor under a
//!   balance constraint), same qualitative behaviour on skewed graphs —
//!   high-degree vertices get replicated first, low-degree vertices stay
//!   whole.
//! * **DBH** (Degree-Based Hashing; Xie et al., NIPS'14) — hash the edge
//!   to the partition of its lower-degree endpoint. Cheaper and slightly
//!   worse RF; used as an ablation baseline.

use super::EdgeAssignment;
use crate::graph::{Csr, KnowledgeGraph};
use crate::util::rng::Rng;

/// HDRF greedy streaming partitioner.
///
/// For each edge (u, v), scores every partition p:
///   C_rep(p)  = g(u, p) + g(v, p)           (replication affinity)
///   C_bal(p)  = λ · (maxsize − |p|) / (ε + maxsize − minsize)
/// where g(w, p) = 1 + (1 − θ_w) if w already replicated in p else 0,
/// θ_w = deg(w) / (deg(u) + deg(v)) — favouring the *lower*-degree
/// endpoint keeps low-degree vertices unreplicated while high-degree
/// vertices (which will be replicated anyway) absorb the cut.
///
/// λ trades replication for balance (λ→0: pure replication greedy; large
/// λ: pure balance). The edge stream order is shuffled deterministically
/// from `seed`, as streaming partitioners are order-sensitive.
pub fn hdrf(g: &KnowledgeGraph, num_partitions: usize, lambda: f64, seed: u64) -> EdgeAssignment {
    hdrf_impl(g, g.degrees(), num_partitions, lambda, seed)
}

/// [`hdrf`] with degrees read off a caller-provided CSR (identical
/// values — same train edges — so the assignment is bit-identical),
/// skipping the extra O(E) degree-counting pass.
pub fn hdrf_with(
    g: &KnowledgeGraph,
    csr: &Csr,
    num_partitions: usize,
    lambda: f64,
    seed: u64,
) -> EdgeAssignment {
    hdrf_impl(g, csr.degrees(), num_partitions, lambda, seed)
}

fn hdrf_impl(
    g: &KnowledgeGraph,
    degrees: Vec<u32>,
    num_partitions: usize,
    lambda: f64,
    seed: u64,
) -> EdgeAssignment {
    let p = num_partitions;
    assert!(p >= 1);
    let n = g.num_entities;

    // replicas[v] = bitset over partitions (supports arbitrary P via Vec).
    let words = p.div_ceil(64);
    let mut replicas = vec![0u64; n * words];
    let has = |replicas: &[u64], v: usize, part: usize| -> bool {
        replicas[v * words + part / 64] >> (part % 64) & 1 == 1
    };
    let set = |replicas: &mut [u64], v: usize, part: usize| {
        replicas[v * words + part / 64] |= 1 << (part % 64);
    };

    let mut sizes = vec![0usize; p];
    // Stream order: sorted by the younger endpoint, with a seeded shuffle
    // *within* ties. Streaming partitioners are order-sensitive; sorted
    // streaming lets the replication-affinity term accumulate locally, so
    // on graphs with temporal/locality structure (citation graphs) HDRF
    // recovers the banded partitions a global optimizer like KaHIP finds,
    // while on unstructured KGs it matches shuffled-order quality.
    let mut order: Vec<u32> = (0..g.train.len() as u32).collect();
    let mut rng = Rng::seeded(seed);
    rng.shuffle(&mut order);
    order.sort_by_key(|&eid| {
        let e = g.train[eid as usize];
        e.s.max(e.t)
    });

    let mut assignment = vec![0u32; g.train.len()];
    const EPS: f64 = 1.0;
    // Hard capacity: no partition may exceed its fair share by >5%. The
    // soft balance term alone cannot prevent affinity chains from
    // collapsing a sorted stream into one partition.
    let capacity = (g.train.len().div_ceil(p) as f64 * 1.05) as usize + 1;

    for &eid in &order {
        let e = g.train[eid as usize];
        let (u, v) = (e.s as usize, e.t as usize);
        let (du, dv) = (degrees[u] as f64, degrees[v] as f64);
        let theta_u = du / (du + dv);
        let theta_v = 1.0 - theta_u;

        let max_size = *sizes.iter().max().unwrap() as f64;
        let min_size = *sizes.iter().min().unwrap() as f64;

        let mut best = 0usize;
        let mut best_score = f64::NEG_INFINITY;
        for part in 0..p {
            if sizes[part] >= capacity {
                continue;
            }
            let g_u = if has(&replicas, u, part) { 1.0 + (1.0 - theta_u) } else { 0.0 };
            let g_v = if has(&replicas, v, part) { 1.0 + (1.0 - theta_v) } else { 0.0 };
            let c_rep = g_u + g_v;
            let c_bal = lambda * (max_size - sizes[part] as f64) / (EPS + max_size - min_size);
            let score = c_rep + c_bal;
            if score > best_score {
                best_score = score;
                best = part;
            }
        }
        assignment[eid as usize] = best as u32;
        sizes[best] += 1;
        set(&mut replicas, u, best);
        set(&mut replicas, v, best);
    }

    EdgeAssignment { num_partitions: p, assignment }
}

/// DBH: assign edge (u, v) to `hash(argmin-degree endpoint) % P`.
pub fn dbh(g: &KnowledgeGraph, num_partitions: usize) -> EdgeAssignment {
    dbh_impl(g, g.degrees(), num_partitions)
}

/// [`dbh`] with degrees read off a caller-provided CSR (bit-identical).
pub fn dbh_with(g: &KnowledgeGraph, csr: &Csr, num_partitions: usize) -> EdgeAssignment {
    dbh_impl(g, csr.degrees(), num_partitions)
}

fn dbh_impl(g: &KnowledgeGraph, degrees: Vec<u32>, num_partitions: usize) -> EdgeAssignment {
    let assignment = g
        .train
        .iter()
        .map(|e| {
            let pick = if degrees[e.s as usize] <= degrees[e.t as usize] { e.s } else { e.t };
            (mix64(pick as u64) % num_partitions as u64) as u32
        })
        .collect();
    EdgeAssignment { num_partitions, assignment }
}

/// Finalizer from SplitMix64 — a good 64-bit hash for vertex ids.
#[inline]
fn mix64(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9E3779B97F4A7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExperimentConfig;
    use crate::graph::generator;

    fn graph() -> KnowledgeGraph {
        let mut cfg = ExperimentConfig::tiny().dataset;
        cfg.entities = 600;
        cfg.train_edges = 5000;
        generator::generate(&cfg)
    }

    fn replication_factor(g: &KnowledgeGraph, a: &EdgeAssignment) -> f64 {
        let mut parts_of: Vec<std::collections::HashSet<u32>> =
            vec![Default::default(); a.num_partitions];
        for (i, e) in g.train.iter().enumerate() {
            let p = a.assignment[i] as usize;
            parts_of[p].insert(e.s);
            parts_of[p].insert(e.t);
        }
        parts_of.iter().map(|s| s.len()).sum::<usize>() as f64 / g.num_entities as f64
    }

    fn balance(a: &EdgeAssignment) -> f64 {
        let mut sizes = vec![0usize; a.num_partitions];
        for &p in &a.assignment {
            sizes[p as usize] += 1;
        }
        *sizes.iter().max().unwrap() as f64 / (*sizes.iter().min().unwrap()).max(1) as f64
    }

    #[test]
    fn hdrf_assigns_every_edge_in_range() {
        let g = graph();
        let a = hdrf(&g, 4, 1.0, 7);
        assert_eq!(a.assignment.len(), g.train.len());
        assert!(a.assignment.iter().all(|&p| p < 4));
    }

    #[test]
    fn hdrf_is_balanced() {
        let g = graph();
        let a = hdrf(&g, 4, 1.0, 7);
        assert!(balance(&a) < 1.3, "HDRF balance {} too skewed", balance(&a));
    }

    #[test]
    fn hdrf_beats_random_on_replication() {
        let g = graph();
        let a = hdrf(&g, 8, 1.0, 7);
        let r = super::super::random::random(&g, 8, 7);
        let rf_hdrf = replication_factor(&g, &a);
        let rf_rand = replication_factor(&g, &r);
        assert!(
            rf_hdrf < rf_rand * 0.9,
            "HDRF RF {rf_hdrf:.2} should beat random RF {rf_rand:.2}"
        );
    }

    #[test]
    fn hdrf_deterministic_given_seed() {
        let g = graph();
        assert_eq!(hdrf(&g, 4, 1.0, 9).assignment, hdrf(&g, 4, 1.0, 9).assignment);
        assert_ne!(hdrf(&g, 4, 1.0, 9).assignment, hdrf(&g, 4, 1.0, 10).assignment);
    }

    #[test]
    fn hdrf_lambda_zero_can_collapse_but_lambda_balances() {
        let g = graph();
        let unbal = hdrf(&g, 4, 0.0, 7);
        let bal = hdrf(&g, 4, 4.0, 7);
        assert!(balance(&bal) <= balance(&unbal) + 1e-9);
    }

    #[test]
    fn dbh_in_range_and_deterministic() {
        let g = graph();
        let a = dbh(&g, 8);
        assert!(a.assignment.iter().all(|&p| p < 8));
        assert_eq!(a.assignment, dbh(&g, 8).assignment);
    }

    #[test]
    fn dbh_groups_low_degree_vertices() {
        // All edges incident to the same low-degree vertex land together
        // when that vertex is the lower-degree endpoint of each edge.
        let g = graph();
        let degrees = g.degrees();
        let a = dbh(&g, 4);
        for (i, e) in g.train.iter().enumerate() {
            let pick = if degrees[e.s as usize] <= degrees[e.t as usize] { e.s } else { e.t };
            let expect = (mix64(pick as u64) % 4) as u32;
            assert_eq!(a.assignment[i], expect);
        }
    }

    #[test]
    fn single_partition_trivial() {
        let g = graph();
        assert!(hdrf(&g, 1, 1.0, 0).assignment.iter().all(|&p| p == 0));
        assert!(dbh(&g, 1).assignment.iter().all(|&p| p == 0));
    }

    #[test]
    fn shared_csr_variants_are_identical() {
        let g = graph();
        let csr = Csr::build(g.num_entities, &g.train);
        assert_eq!(hdrf_with(&g, &csr, 4, 1.0, 9).assignment, hdrf(&g, 4, 1.0, 9).assignment);
        assert_eq!(dbh_with(&g, &csr, 8).assignment, dbh(&g, 8).assignment);
    }
}

//! Graph partitioning for distributed training (paper §3.2).
//!
//! The pipeline is two-phase, exactly as in the paper:
//!
//! 1. **Partitioning** — divide the *train edges* into `P` disjoint sets
//!    ("core edges"). Strategies:
//!    * [`vertex_cut`] — HDRF and DBH streaming vertex-cut partitioners
//!      (replication-minimizing, balanced — the KaHIP stand-in);
//!    * [`edge_cut`] — greedy vertex partitioning whose 1-hop edges form
//!      the core set (the METIS stand-in, reproducing edge replication);
//!    * [`random`] — uniform random edge assignment (paper baseline).
//! 2. **Neighborhood expansion** ([`expansion`]) — add the n-hop
//!    dependency closure of each partition's core vertices as
//!    *support vertices/edges*, making each partition self-sufficient:
//!    message passing for any core edge never leaves the partition.
//!
//! [`stats`] computes the paper's partition-quality metrics (core/total
//! edges, replication factor RF of Eq. 7) that fill Tables 2 and 5.

pub mod edge_cut;
pub mod expansion;
pub mod random;
pub mod stats;
pub mod vertex_cut;

use crate::config::{PartitionConfig, PartitionStrategy};
use crate::graph::{KnowledgeGraph, Triple};

/// Which role a vertex plays inside one partition (paper §3.2.1-3.2.2).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum VertexRole {
    /// Endpoint of a core edge, not replicated boundary.
    Core,
    /// Cut vertex replicated into several partitions.
    Replicated,
    /// Added by neighborhood expansion only (no core edge touches it).
    Support,
}

/// One self-sufficient partition after expansion.
///
/// Vertices and edges are stored with *global* ids; `local_of`/`vertices`
/// provide the dense local numbering used to build compute graphs.
#[derive(Clone, Debug)]
pub struct Partition {
    pub id: usize,
    /// Global ids of every vertex present (core ∪ replicated ∪ support),
    /// sorted ascending; index in this vec == local id.
    pub vertices: Vec<u32>,
    /// Role of each vertex, parallel to `vertices`.
    pub roles: Vec<VertexRole>,
    /// Core (training-positive) edges — a disjoint cover across partitions.
    pub core_edges: Vec<Triple>,
    /// Support edges added by expansion (message passing only, never
    /// scored as positives).
    pub support_edges: Vec<Triple>,
}

impl Partition {
    /// Total edges = core + support (the paper's "total edges" column).
    pub fn total_edges(&self) -> usize {
        self.core_edges.len() + self.support_edges.len()
    }

    /// Local id of a global vertex (None if absent).
    pub fn local_of(&self, global: u32) -> Option<u32> {
        self.vertices.binary_search(&global).ok().map(|i| i as u32)
    }

    /// Global ids of core vertices (endpoints of core edges) — the
    /// constraint-based negative sampler draws from exactly this set.
    pub fn core_vertex_ids(&self) -> Vec<u32> {
        self.vertices
            .iter()
            .zip(&self.roles)
            .filter(|(_, role)| !matches!(role, VertexRole::Support))
            .map(|(v, _)| *v)
            .collect()
    }
}

/// An edge-disjoint pre-expansion assignment: `assignment[i]` = partition
/// of train edge `i`.
#[derive(Clone, Debug)]
pub struct EdgeAssignment {
    pub num_partitions: usize,
    pub assignment: Vec<u32>,
}

/// Run the configured strategy, returning the pre-expansion assignment.
pub fn assign_edges(g: &KnowledgeGraph, cfg: &PartitionConfig, seed: u64) -> EdgeAssignment {
    match cfg.strategy {
        PartitionStrategy::Hdrf => {
            vertex_cut::hdrf(g, cfg.num_partitions, cfg.hdrf_lambda, seed)
        }
        PartitionStrategy::Dbh => vertex_cut::dbh(g, cfg.num_partitions),
        PartitionStrategy::MetisLike => edge_cut::metis_like(g, cfg.num_partitions, seed),
        PartitionStrategy::Random => random::random(g, cfg.num_partitions, seed),
    }
}

/// Full two-phase pipeline: assignment + neighborhood expansion.
pub fn partition_graph(g: &KnowledgeGraph, cfg: &PartitionConfig, seed: u64) -> Vec<Partition> {
    let assignment = assign_edges(g, cfg, seed);
    expansion::expand(g, &assignment, cfg.hops)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExperimentConfig;
    use crate::graph::generator;

    #[test]
    fn every_strategy_produces_disjoint_cover() {
        let g = generator::generate(&ExperimentConfig::tiny().dataset);
        for strategy in [
            PartitionStrategy::Hdrf,
            PartitionStrategy::Dbh,
            PartitionStrategy::MetisLike,
            PartitionStrategy::Random,
        ] {
            let cfg = PartitionConfig { strategy, num_partitions: 4, hops: 2, hdrf_lambda: 1.0 };
            let parts = partition_graph(&g, &cfg, 42);
            assert_eq!(parts.len(), 4, "{strategy:?}");
            let total_core: usize = parts.iter().map(|p| p.core_edges.len()).sum();
            assert_eq!(total_core, g.train.len(), "{strategy:?}: core edges must cover train set");
            // Disjoint: no triple in two partitions' core sets.
            let mut seen = std::collections::HashSet::new();
            for p in &parts {
                for e in &p.core_edges {
                    assert!(seen.insert(e.key()), "{strategy:?}: duplicated core edge {e:?}");
                }
            }
        }
    }

    #[test]
    fn single_partition_is_whole_graph() {
        let g = generator::generate(&ExperimentConfig::tiny().dataset);
        let cfg = PartitionConfig {
            strategy: PartitionStrategy::Hdrf,
            num_partitions: 1,
            hops: 2,
            hdrf_lambda: 1.0,
        };
        let parts = partition_graph(&g, &cfg, 1);
        assert_eq!(parts.len(), 1);
        assert_eq!(parts[0].core_edges.len(), g.train.len());
        assert!(parts[0].support_edges.is_empty(), "nothing to expand with P=1");
    }

    #[test]
    fn local_of_roundtrips() {
        let g = generator::generate(&ExperimentConfig::tiny().dataset);
        let cfg = PartitionConfig {
            strategy: PartitionStrategy::Hdrf,
            num_partitions: 2,
            hops: 2,
            hdrf_lambda: 1.0,
        };
        let parts = partition_graph(&g, &cfg, 1);
        for p in &parts {
            for (local, &global) in p.vertices.iter().enumerate() {
                assert_eq!(p.local_of(global), Some(local as u32));
            }
            assert_eq!(p.local_of(u32::MAX), None);
        }
    }
}

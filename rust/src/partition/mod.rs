//! Graph partitioning for distributed training (paper §3.2).
//!
//! The pipeline is two-phase, exactly as in the paper:
//!
//! 1. **Partitioning** — divide the *train edges* into `P` disjoint sets
//!    ("core edges"). Strategies:
//!    * [`vertex_cut`] — HDRF and DBH streaming vertex-cut partitioners
//!      (replication-minimizing, balanced — the KaHIP stand-in);
//!    * [`edge_cut`] — greedy vertex partitioning whose 1-hop edges form
//!      the core set (the METIS stand-in, reproducing edge replication);
//!    * [`random`] — uniform random edge assignment (paper baseline).
//! 2. **Neighborhood expansion** ([`expansion`]) — add the n-hop
//!    dependency closure of each partition's core vertices as
//!    *support vertices/edges*, making each partition self-sufficient:
//!    message passing for any core edge never leaves the partition.
//!
//! [`stats`] computes the paper's partition-quality metrics (core/total
//! edges, replication factor RF of Eq. 7) that fill Tables 2 and 5.
//!
//! [`build_partitions`] is the production entry point: it shares one CSR
//! between assignment and expansion, fans expansion out across
//! `partition.build_threads` workers (bit-identical to sequential), and
//! memoizes the whole build in an on-disk [`cache`] keyed by graph
//! content + config + seed, reporting per-stage timings.

pub mod cache;
pub mod edge_cut;
pub mod expansion;
pub mod random;
pub mod stats;
pub mod vertex_cut;

use crate::config::{PartitionConfig, PartitionStrategy};
use crate::graph::{Csr, KnowledgeGraph, Triple};
use crate::util::timer::Stopwatch;
use std::path::PathBuf;

/// Which role a vertex plays inside one partition (paper §3.2.1-3.2.2).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum VertexRole {
    /// Endpoint of a core edge, not replicated boundary.
    Core,
    /// Cut vertex replicated into several partitions.
    Replicated,
    /// Added by neighborhood expansion only (no core edge touches it).
    Support,
}

/// One self-sufficient partition after expansion.
///
/// Vertices and edges are stored with *global* ids; `local_of`/`vertices`
/// provide the dense local numbering used to build compute graphs.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Partition {
    pub id: usize,
    /// Global ids of every vertex present (core ∪ replicated ∪ support),
    /// sorted ascending; index in this vec == local id.
    pub vertices: Vec<u32>,
    /// Role of each vertex, parallel to `vertices`.
    pub roles: Vec<VertexRole>,
    /// Core (training-positive) edges — a disjoint cover across partitions.
    pub core_edges: Vec<Triple>,
    /// Support edges added by expansion (message passing only, never
    /// scored as positives).
    pub support_edges: Vec<Triple>,
}

impl Partition {
    /// Total edges = core + support (the paper's "total edges" column).
    pub fn total_edges(&self) -> usize {
        self.core_edges.len() + self.support_edges.len()
    }

    /// Local id of a global vertex (None if absent).
    pub fn local_of(&self, global: u32) -> Option<u32> {
        self.vertices.binary_search(&global).ok().map(|i| i as u32)
    }

    /// Global ids of core vertices (endpoints of core edges) — the
    /// constraint-based negative sampler draws from exactly this set.
    pub fn core_vertex_ids(&self) -> Vec<u32> {
        self.vertices
            .iter()
            .zip(&self.roles)
            .filter(|(_, role)| !matches!(role, VertexRole::Support))
            .map(|(v, _)| *v)
            .collect()
    }
}

/// An edge-disjoint pre-expansion assignment: `assignment[i]` = partition
/// of train edge `i`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct EdgeAssignment {
    pub num_partitions: usize,
    pub assignment: Vec<u32>,
}

/// Run the configured strategy, returning the pre-expansion assignment.
pub fn assign_edges(g: &KnowledgeGraph, cfg: &PartitionConfig, seed: u64) -> EdgeAssignment {
    match cfg.strategy {
        PartitionStrategy::Hdrf => {
            vertex_cut::hdrf(g, cfg.num_partitions, cfg.hdrf_lambda, seed)
        }
        PartitionStrategy::Dbh => vertex_cut::dbh(g, cfg.num_partitions),
        PartitionStrategy::MetisLike => edge_cut::metis_like(g, cfg.num_partitions, seed),
        PartitionStrategy::Random => random::random(g, cfg.num_partitions, seed),
    }
}

/// [`assign_edges`] over a caller-provided CSR, so one CSR build serves
/// both assignment and expansion. Bit-identical to [`assign_edges`]:
/// each strategy's `_with` variant reads the same degrees/adjacency it
/// would have rebuilt itself (Random never needed them).
pub fn assign_edges_with(
    g: &KnowledgeGraph,
    csr: &Csr,
    cfg: &PartitionConfig,
    seed: u64,
) -> EdgeAssignment {
    match cfg.strategy {
        PartitionStrategy::Hdrf => {
            vertex_cut::hdrf_with(g, csr, cfg.num_partitions, cfg.hdrf_lambda, seed)
        }
        PartitionStrategy::Dbh => vertex_cut::dbh_with(g, csr, cfg.num_partitions),
        PartitionStrategy::MetisLike => {
            edge_cut::metis_like_with(g, csr, cfg.num_partitions, seed)
        }
        PartitionStrategy::Random => random::random(g, cfg.num_partitions, seed),
    }
}

/// Full two-phase pipeline: assignment + neighborhood expansion.
///
/// Kept as the simple no-cache, no-stats entry point for tests and
/// one-shot callers; [`build_partitions`] is the production path.
pub fn partition_graph(g: &KnowledgeGraph, cfg: &PartitionConfig, seed: u64) -> Vec<Partition> {
    let csr = Csr::build(g.num_entities, &g.train);
    let assignment = assign_edges_with(g, &csr, cfg, seed);
    expansion::expand_with(g, &csr, &assignment, cfg.hops, cfg.build_threads)
}

/// How one partition build went: wall time, per-stage breakdown, and
/// cache outcome. Reported next to the replication-factor stats.
#[derive(Clone, Debug, Default)]
pub struct PartitionBuildStats {
    pub wall_secs: f64,
    /// Edge-assignment stage (includes the shared CSR build).
    pub assign_secs: f64,
    /// Neighborhood-expansion stage.
    pub expand_secs: f64,
    /// Cache probe + load/save time.
    pub cache_io_secs: f64,
    pub cache_hit: bool,
    /// Cache file used (read or written); `None` when caching is off.
    pub cache_path: Option<PathBuf>,
    pub build_threads: usize,
}

impl PartitionBuildStats {
    /// One-line human summary for run logs.
    pub fn summary(&self) -> String {
        let cache = match (&self.cache_path, self.cache_hit) {
            (None, _) => "off".to_string(),
            (Some(p), true) => format!("hit {}", p.display()),
            (Some(p), false) => format!("miss -> wrote {}", p.display()),
        };
        format!(
            "partition build {:.3}s (assign {:.3}s, expand {:.3}s, cache-io {:.3}s, \
             threads {}, cache {})",
            self.wall_secs,
            self.assign_secs,
            self.expand_secs,
            self.cache_io_secs,
            self.build_threads,
            cache
        )
    }
}

/// Production partition build: cache probe, shared-CSR assignment,
/// multi-threaded expansion, cache write-back — with per-stage timings.
///
/// The output `Vec<Partition>` is bit-identical to
/// [`partition_graph`] (and to a `build_threads = 0` sequential build)
/// whether it was rebuilt or loaded from cache. Cache problems are
/// never fatal: a stale, corrupt, or unwritable entry logs a warning
/// and the build proceeds from scratch.
pub fn build_partitions(
    g: &KnowledgeGraph,
    cfg: &PartitionConfig,
    seed: u64,
) -> (Vec<Partition>, PartitionBuildStats) {
    let wall = Stopwatch::new();
    let mut stats = PartitionBuildStats { build_threads: cfg.build_threads, ..Default::default() };

    let cache_target = if cfg.cache_dir.is_empty() {
        None
    } else {
        let key = cache::cache_key(g, cfg, seed);
        Some((key, cache::cache_file(std::path::Path::new(&cfg.cache_dir), cfg, key)))
    };

    if let Some((key, path)) = &cache_target {
        let mut sw = Stopwatch::new();
        if path.exists() {
            match cache::load(path, *key, g, cfg) {
                Ok((_assignment, parts)) => {
                    stats.cache_io_secs = sw.lap_secs();
                    stats.cache_hit = true;
                    stats.cache_path = Some(path.clone());
                    stats.wall_secs = wall.elapsed_secs();
                    return (parts, stats);
                }
                Err(e) => {
                    crate::log_warn!(
                        "partition cache at {} unusable ({e:#}); rebuilding",
                        path.display()
                    );
                }
            }
        }
        stats.cache_io_secs += sw.lap_secs();
    }

    let mut sw = Stopwatch::new();
    let csr = Csr::build(g.num_entities, &g.train);
    let assignment = assign_edges_with(g, &csr, cfg, seed);
    stats.assign_secs = sw.lap_secs();
    let parts = expansion::expand_with(g, &csr, &assignment, cfg.hops, cfg.build_threads);
    stats.expand_secs = sw.lap_secs();

    if let Some((key, path)) = &cache_target {
        if let Err(e) = cache::save(path, *key, cfg, seed, &assignment, &parts) {
            crate::log_warn!("failed to write partition cache {} ({e:#})", path.display());
        } else {
            stats.cache_path = Some(path.clone());
        }
        stats.cache_io_secs += sw.lap_secs();
    }
    stats.wall_secs = wall.elapsed_secs();
    (parts, stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExperimentConfig;
    use crate::graph::generator;

    #[test]
    fn every_strategy_produces_disjoint_cover() {
        let g = generator::generate(&ExperimentConfig::tiny().dataset);
        for strategy in [
            PartitionStrategy::Hdrf,
            PartitionStrategy::Dbh,
            PartitionStrategy::MetisLike,
            PartitionStrategy::Random,
        ] {
            let cfg =
                PartitionConfig { strategy, num_partitions: 4, hops: 2, ..Default::default() };
            let parts = partition_graph(&g, &cfg, 42);
            assert_eq!(parts.len(), 4, "{strategy:?}");
            let total_core: usize = parts.iter().map(|p| p.core_edges.len()).sum();
            assert_eq!(total_core, g.train.len(), "{strategy:?}: core edges must cover train set");
            // Disjoint: no triple in two partitions' core sets.
            let mut seen = std::collections::HashSet::new();
            for p in &parts {
                for e in &p.core_edges {
                    assert!(seen.insert(e.key()), "{strategy:?}: duplicated core edge {e:?}");
                }
            }
        }
    }

    #[test]
    fn single_partition_is_whole_graph() {
        let g = generator::generate(&ExperimentConfig::tiny().dataset);
        let cfg = PartitionConfig { num_partitions: 1, ..Default::default() };
        let parts = partition_graph(&g, &cfg, 1);
        assert_eq!(parts.len(), 1);
        assert_eq!(parts[0].core_edges.len(), g.train.len());
        assert!(parts[0].support_edges.is_empty(), "nothing to expand with P=1");
    }

    #[test]
    fn local_of_roundtrips() {
        let g = generator::generate(&ExperimentConfig::tiny().dataset);
        let cfg = PartitionConfig { num_partitions: 2, ..Default::default() };
        let parts = partition_graph(&g, &cfg, 1);
        for p in &parts {
            for (local, &global) in p.vertices.iter().enumerate() {
                assert_eq!(p.local_of(global), Some(local as u32));
            }
            assert_eq!(p.local_of(u32::MAX), None);
        }
    }

    fn cache_cfg(tag: &str) -> PartitionConfig {
        let dir = std::env::temp_dir()
            .join(format!("kgscale-buildcache-{tag}-{}", std::process::id()));
        PartitionConfig {
            num_partitions: 4,
            cache_dir: dir.to_string_lossy().into_owned(),
            ..Default::default()
        }
    }

    #[test]
    fn build_partitions_matches_partition_graph_and_hits_cache() {
        let g = generator::generate(&ExperimentConfig::tiny().dataset);
        let cfg = cache_cfg("roundtrip");
        let reference = partition_graph(&g, &cfg, 42);

        let (cold, s1) = build_partitions(&g, &cfg, 42);
        assert_eq!(cold, reference, "rebuilt output must match the plain pipeline");
        assert!(!s1.cache_hit, "first build must miss");
        let path = s1.cache_path.clone().expect("cache write should have succeeded");
        assert!(path.exists());

        let (warm, s2) = build_partitions(&g, &cfg, 42);
        assert_eq!(warm, reference, "cached output must be bit-identical");
        assert!(s2.cache_hit, "second build must hit");
        assert!(s2.summary().contains("cache hit"), "got: {}", s2.summary());

        std::fs::remove_dir_all(std::path::Path::new(&cfg.cache_dir)).unwrap();
    }

    #[test]
    fn build_partitions_without_cache_dir_skips_cache() {
        let g = generator::generate(&ExperimentConfig::tiny().dataset);
        let cfg = PartitionConfig { num_partitions: 2, ..Default::default() };
        let (parts, stats) = build_partitions(&g, &cfg, 7);
        assert_eq!(parts, partition_graph(&g, &cfg, 7));
        assert!(!stats.cache_hit);
        assert!(stats.cache_path.is_none());
        assert!(stats.summary().contains("cache off"));
    }

    #[test]
    fn corrupt_cache_falls_back_to_rebuild() {
        let g = generator::generate(&ExperimentConfig::tiny().dataset);
        let cfg = cache_cfg("corrupt");
        let (reference, s1) = build_partitions(&g, &cfg, 9);
        let path = s1.cache_path.clone().unwrap();
        std::fs::write(&path, b"definitely not a partition cache").unwrap();

        let (parts, s2) = build_partitions(&g, &cfg, 9);
        assert_eq!(parts, reference, "corrupt cache must rebuild identically");
        assert!(!s2.cache_hit, "corrupt entry must count as a miss");
        // The rebuild overwrote the bad entry, so a third build hits.
        let (_, s3) = build_partitions(&g, &cfg, 9);
        assert!(s3.cache_hit);

        std::fs::remove_dir_all(std::path::Path::new(&cfg.cache_dir)).unwrap();
    }

    #[test]
    fn changed_seed_or_config_misses_cache() {
        let g = generator::generate(&ExperimentConfig::tiny().dataset);
        let cfg = cache_cfg("miss");
        let (_, s1) = build_partitions(&g, &cfg, 1);
        assert!(!s1.cache_hit);

        // Different seed -> different key -> different file -> miss.
        let (_, s2) = build_partitions(&g, &cfg, 2);
        assert!(!s2.cache_hit);
        assert_ne!(s1.cache_path, s2.cache_path);

        // Different expansion depth -> miss (hops is in both key and name).
        let cfg_h1 = PartitionConfig { hops: 1, ..cfg.clone() };
        let (_, s3) = build_partitions(&g, &cfg_h1, 1);
        assert!(!s3.cache_hit);

        // Unchanged inputs still hit.
        let (_, s4) = build_partitions(&g, &cfg, 1);
        assert!(s4.cache_hit);

        std::fs::remove_dir_all(std::path::Path::new(&cfg.cache_dir)).unwrap();
    }
}

//! Edge-cut (vertex-partitioning) baseline — the METIS stand-in.
//!
//! The paper's §4.5.5 comparison partitions *vertices* with METIS and
//! then takes "the first hop neighbors of vertices [as] the core edges of
//! a partition". We reproduce that pipeline with a greedy BFS-grow
//! vertex partitioner in the spirit of multilevel/LDG partitioners:
//! grow P balanced vertex sets region-by-region (BFS from seeds, picking
//! the frontier vertex with the most already-assigned neighbors — the
//! same "minimize cut" greedy objective METIS optimizes), then assign
//! each train edge to the partition that owns its *source* vertex.
//!
//! The failure mode the paper exploits is structural, not METIS-specific:
//! a vertex partition's 1-hop core edges replicate every cut edge into
//! two partitions' neighborhoods, and neighborhood expansion then blows
//! the partitions up ("approximately 33% larger ... increases the
//! training time by approximately 21%"). Any reasonable balanced vertex
//! partitioner reproduces it; ours yields the same shape.

use super::EdgeAssignment;
use crate::graph::{Csr, KnowledgeGraph};
use crate::util::rng::Rng;
use std::collections::BinaryHeap;

/// Greedy BFS-grow vertex partitioning + source-vertex edge assignment.
pub fn metis_like(g: &KnowledgeGraph, num_partitions: usize, seed: u64) -> EdgeAssignment {
    let csr = Csr::build(g.num_entities, &g.train);
    metis_like_with(g, &csr, num_partitions, seed)
}

/// [`metis_like`] with a caller-provided CSR, so the build pipeline can
/// share one CSR between assignment and neighborhood expansion.
pub fn metis_like_with(
    g: &KnowledgeGraph,
    csr: &Csr,
    num_partitions: usize,
    seed: u64,
) -> EdgeAssignment {
    let owner = partition_vertices_with(g, csr, num_partitions, seed);
    // Edge -> partition of its source vertex ("first hop neighbors of
    // vertices are the core edges", §4.5.5).
    let assignment = g.train.iter().map(|e| owner[e.s as usize]).collect();
    EdgeAssignment { num_partitions, assignment }
}

/// Balanced greedy region growing. Returns owner[vertex] -> partition.
pub fn partition_vertices(g: &KnowledgeGraph, num_partitions: usize, seed: u64) -> Vec<u32> {
    let csr = Csr::build(g.num_entities, &g.train);
    partition_vertices_with(g, &csr, num_partitions, seed)
}

/// [`partition_vertices`] over a caller-provided CSR.
pub fn partition_vertices_with(
    g: &KnowledgeGraph,
    csr: &Csr,
    num_partitions: usize,
    seed: u64,
) -> Vec<u32> {
    let n = g.num_entities;
    let p = num_partitions;
    let target = n.div_ceil(p);
    let mut owner = vec![u32::MAX; n];
    let mut sizes = vec![0usize; p];
    let mut rng = Rng::seeded(seed);

    // Seed each region at a random unassigned vertex, round-robin grow.
    // Frontier heaps are keyed by "gain" = number of already-owned
    // neighbors in this region (greedy min-cut).
    let mut heaps: Vec<BinaryHeap<(i64, u32)>> = vec![BinaryHeap::new(); p];
    let mut order: Vec<u32> = (0..n as u32).collect();
    rng.shuffle(&mut order);
    let mut seed_cursor = 0usize;

    // Neighbor walk straight off the CSR slices (out-targets first, then
    // in-sources — the order the old per-call `Vec` used). The gain scan
    // below runs once per (popped vertex, unassigned neighbor) pair, so
    // an allocating walk here was O(Σdeg²) heap traffic per region pop.
    let neighbors = |v: u32| {
        csr.out_edges(v)
            .iter()
            .map(|&eid| g.train[eid as usize].t)
            .chain(csr.in_edges(v).iter().map(|&eid| g.train[eid as usize].s))
    };

    let mut assigned = 0usize;
    while assigned < n {
        for part in 0..p {
            if assigned >= n || sizes[part] >= target {
                continue;
            }
            // Pop the best unassigned frontier vertex; reseed if empty.
            let v = loop {
                match heaps[part].pop() {
                    Some((_, v)) if owner[v as usize] == u32::MAX => break Some(v),
                    Some(_) => continue, // stale entry
                    None => {
                        // find a fresh seed
                        let mut found = None;
                        while seed_cursor < n {
                            let cand = order[seed_cursor];
                            seed_cursor += 1;
                            if owner[cand as usize] == u32::MAX {
                                found = Some(cand);
                                break;
                            }
                        }
                        break found;
                    }
                }
            };
            let Some(v) = v else { continue };
            owner[v as usize] = part as u32;
            sizes[part] += 1;
            assigned += 1;
            // Push neighbors with updated gains.
            for w in neighbors(v) {
                if owner[w as usize] == u32::MAX {
                    let gain =
                        neighbors(w).filter(|&x| owner[x as usize] == part as u32).count() as i64;
                    heaps[part].push((gain, w));
                }
            }
        }
    }
    owner
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExperimentConfig;
    use crate::graph::generator;

    fn graph() -> KnowledgeGraph {
        let mut cfg = ExperimentConfig::tiny().dataset;
        cfg.entities = 600;
        cfg.train_edges = 5000;
        generator::generate(&cfg)
    }

    #[test]
    fn vertex_partition_is_total_and_balanced() {
        let g = graph();
        let owner = partition_vertices(&g, 4, 3);
        assert!(owner.iter().all(|&o| o < 4));
        let mut sizes = [0usize; 4];
        for &o in &owner {
            sizes[o as usize] += 1;
        }
        let max = *sizes.iter().max().unwrap() as f64;
        let min = *sizes.iter().min().unwrap() as f64;
        assert!(max / min < 1.5, "vertex balance too skewed: {sizes:?}");
    }

    #[test]
    fn edges_follow_source_owner() {
        let g = graph();
        let owner = partition_vertices(&g, 4, 3);
        let a = metis_like(&g, 4, 3);
        for (i, e) in g.train.iter().enumerate() {
            assert_eq!(a.assignment[i], owner[e.s as usize]);
        }
    }

    #[test]
    fn locality_better_than_random() {
        // Fraction of edges whose both endpoints share a partition should
        // beat the random-expected 1/P.
        let g = graph();
        let owner = partition_vertices(&g, 4, 3);
        let internal = g
            .train
            .iter()
            .filter(|e| owner[e.s as usize] == owner[e.t as usize])
            .count() as f64
            / g.train.len() as f64;
        assert!(internal > 0.3, "greedy grow found no locality: internal={internal:.3}");
    }

    #[test]
    fn deterministic_per_seed() {
        let g = graph();
        assert_eq!(metis_like(&g, 4, 5).assignment, metis_like(&g, 4, 5).assignment);
    }

    #[test]
    fn shared_csr_variant_is_identical() {
        let g = graph();
        let csr = Csr::build(g.num_entities, &g.train);
        assert_eq!(metis_like_with(&g, &csr, 4, 3).assignment, metis_like(&g, 4, 3).assignment);
        assert_eq!(partition_vertices_with(&g, &csr, 4, 3), partition_vertices(&g, 4, 3));
    }
}

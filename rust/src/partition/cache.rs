//! On-disk partition cache.
//!
//! Self-sufficient partition construction (assignment + n-hop
//! neighborhood expansion) is deterministic in the graph, the
//! [`PartitionConfig`], and the seed — so its output can be memoized
//! across runs. Every eval-only rerun, bench repeat, or resumed
//! experiment on an identical config previously rebuilt partitions from
//! nothing; with a cache dir configured (`partition.cache_dir`), the
//! second run loads them instead and skips both stages.
//!
//! **Cache key.** A 64-bit FNV-1a content hash over: a format tag, the
//! entity/relation counts, every train-edge triple's bytes, the full
//! partition config (strategy, P, hops, λ bits), and the seed. Any
//! change to those invalidates the entry. A stale or corrupt file is
//! *never* an error: `partition::build_partitions` logs a warning and
//! rebuilds (then overwrites the entry).
//!
//! **File layout** (little-endian), one file per key:
//!
//! ```text
//! magic "KGPC" | version u32 | key u64
//! | build manifest: strategy (len u32 + utf8) | P u64 | hops u64
//! |                 λ f64-bits u64 | seed u64
//! | assignment: train_edges u64 | u32[train_edges]
//! | partitions u64, then per partition:
//! |   id u64 | #vertices u64 | #core u64 | #support u64
//! |   vertices u32[] | roles u8[] | core (s,r,t) u32[] | support u32[]
//! ```

use super::{EdgeAssignment, Partition, VertexRole};
use crate::config::PartitionConfig;
use crate::graph::{KnowledgeGraph, Triple};
use crate::util::hash::Fnv64;
use anyhow::{ensure, Context, Result};
use std::io::{Read, Write};
use std::path::{Path, PathBuf};

const MAGIC: &[u8; 4] = b"KGPC";
const VERSION: u32 = 1;

/// Content hash identifying one partition build: graph identity
/// (entity/relation counts + train-edge bytes) + full partition config
/// + seed. Valid/test splits are deliberately excluded — partitioning
/// only ever reads train edges.
pub fn cache_key(g: &KnowledgeGraph, cfg: &PartitionConfig, seed: u64) -> u64 {
    let mut h = Fnv64::new();
    h.write(b"kgscale-partition-cache-v1");
    h.write_u64(g.num_entities as u64);
    h.write_u64(g.num_relations as u64);
    h.write_u64(g.train.len() as u64);
    for e in &g.train {
        h.write_u32(e.s);
        h.write_u32(e.r);
        h.write_u32(e.t);
    }
    h.write(cfg.strategy.name().as_bytes());
    h.write_u64(cfg.num_partitions as u64);
    h.write_u64(cfg.hops as u64);
    h.write_u64(cfg.hdrf_lambda.to_bits());
    h.write_u64(seed);
    h.finish()
}

/// Cache file for a key: `<dir>/<strategy>-p<P>-h<hops>-<key>.kgpart`.
/// The human-readable prefix aids `ls`-level debugging; only the key
/// byte in the file decides validity.
pub fn cache_file(dir: &Path, cfg: &PartitionConfig, key: u64) -> PathBuf {
    dir.join(format!(
        "{}-p{}-h{}-{key:016x}.kgpart",
        cfg.strategy.name(),
        cfg.num_partitions,
        cfg.hops
    ))
}

fn role_tag(role: VertexRole) -> u8 {
    match role {
        VertexRole::Core => 0,
        VertexRole::Replicated => 1,
        VertexRole::Support => 2,
    }
}

fn role_from_tag(tag: u8) -> Result<VertexRole> {
    match tag {
        0 => Ok(VertexRole::Core),
        1 => Ok(VertexRole::Replicated),
        2 => Ok(VertexRole::Support),
        other => anyhow::bail!("bad vertex-role tag {other}"),
    }
}

fn write_u32s(w: &mut impl Write, xs: &[u32]) -> Result<()> {
    for &x in xs {
        w.write_all(&x.to_le_bytes())?;
    }
    Ok(())
}

fn write_triples(w: &mut impl Write, ts: &[Triple]) -> Result<()> {
    for t in ts {
        w.write_all(&t.s.to_le_bytes())?;
        w.write_all(&t.r.to_le_bytes())?;
        w.write_all(&t.t.to_le_bytes())?;
    }
    Ok(())
}

/// Serialize assignment + partitions under `key`. Writes to a temp file
/// in the same directory, then renames — a crashed writer leaves a
/// `.tmp` orphan, never a torn `.kgpart` that a later run half-parses.
pub fn save(
    path: &Path,
    key: u64,
    cfg: &PartitionConfig,
    seed: u64,
    assignment: &EdgeAssignment,
    parts: &[Partition],
) -> Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir).with_context(|| format!("creating cache dir {dir:?}"))?;
    }
    let tmp = path.with_extension("kgpart.tmp");
    {
        let file = std::fs::File::create(&tmp).with_context(|| format!("creating {tmp:?}"))?;
        let mut w = std::io::BufWriter::new(file);
        w.write_all(MAGIC)?;
        w.write_all(&VERSION.to_le_bytes())?;
        w.write_all(&key.to_le_bytes())?;
        // Build manifest (informational; the key is authoritative).
        let strategy = cfg.strategy.name().as_bytes();
        w.write_all(&(strategy.len() as u32).to_le_bytes())?;
        w.write_all(strategy)?;
        w.write_all(&(cfg.num_partitions as u64).to_le_bytes())?;
        w.write_all(&(cfg.hops as u64).to_le_bytes())?;
        w.write_all(&cfg.hdrf_lambda.to_bits().to_le_bytes())?;
        w.write_all(&seed.to_le_bytes())?;
        // Pre-expansion assignment.
        w.write_all(&(assignment.assignment.len() as u64).to_le_bytes())?;
        write_u32s(&mut w, &assignment.assignment)?;
        // Expanded partitions.
        w.write_all(&(parts.len() as u64).to_le_bytes())?;
        for p in parts {
            w.write_all(&(p.id as u64).to_le_bytes())?;
            w.write_all(&(p.vertices.len() as u64).to_le_bytes())?;
            w.write_all(&(p.core_edges.len() as u64).to_le_bytes())?;
            w.write_all(&(p.support_edges.len() as u64).to_le_bytes())?;
            write_u32s(&mut w, &p.vertices)?;
            for &r in &p.roles {
                w.write_all(&[role_tag(r)])?;
            }
            write_triples(&mut w, &p.core_edges)?;
            write_triples(&mut w, &p.support_edges)?;
        }
        w.flush()?;
    }
    std::fs::rename(&tmp, path).with_context(|| format!("renaming {tmp:?} -> {path:?}"))?;
    Ok(())
}

fn read_u64(r: &mut impl Read) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

fn read_u32s(r: &mut impl Read, n: usize) -> Result<Vec<u32>> {
    let mut bytes = vec![0u8; n * 4];
    r.read_exact(&mut bytes)?;
    Ok(bytes.chunks_exact(4).map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect())
}

fn read_triples(r: &mut impl Read, n: usize) -> Result<Vec<Triple>> {
    let words = read_u32s(r, n * 3)?;
    Ok(words.chunks_exact(3).map(|c| Triple::new(c[0], c[1], c[2])).collect())
}

/// Load a cache file, validating magic, version, key, and structural
/// sanity against the graph + config the caller is about to build for.
/// Every failure mode is an `Err` — the caller treats it as a miss.
pub fn load(
    path: &Path,
    expected_key: u64,
    g: &KnowledgeGraph,
    cfg: &PartitionConfig,
) -> Result<(EdgeAssignment, Vec<Partition>)> {
    let file = std::fs::File::open(path).with_context(|| format!("opening {path:?}"))?;
    let mut r = std::io::BufReader::new(file);
    let mut magic = [0u8; 4];
    r.read_exact(&mut magic)?;
    ensure!(&magic == MAGIC, "not a partition cache file");
    let mut u32b = [0u8; 4];
    r.read_exact(&mut u32b)?;
    let version = u32::from_le_bytes(u32b);
    ensure!(version == VERSION, "unsupported partition cache version {version}");
    let key = read_u64(&mut r)?;
    ensure!(
        key == expected_key,
        "stale cache: key {key:016x} != expected {expected_key:016x} \
         (graph, partition config, or seed changed)"
    );
    // Build manifest: validated against the requesting config, although
    // a key match already implies it — defense against hash collisions
    // costs four comparisons.
    r.read_exact(&mut u32b)?;
    let strategy_len = u32::from_le_bytes(u32b) as usize;
    ensure!(strategy_len <= 64, "implausible strategy-name length {strategy_len}");
    let mut strategy = vec![0u8; strategy_len];
    r.read_exact(&mut strategy)?;
    ensure!(strategy == cfg.strategy.name().as_bytes(), "cached strategy mismatch");
    let p = read_u64(&mut r)? as usize;
    ensure!(p == cfg.num_partitions, "cached partition count mismatch");
    let hops = read_u64(&mut r)? as usize;
    ensure!(hops == cfg.hops, "cached hops mismatch");
    let _lambda_bits = read_u64(&mut r)?;
    let _seed = read_u64(&mut r)?;
    // Assignment.
    let n_edges = read_u64(&mut r)? as usize;
    ensure!(n_edges == g.train.len(), "cached assignment covers {n_edges} train edges");
    let assignment_vec = read_u32s(&mut r, n_edges)?;
    ensure!(
        assignment_vec.iter().all(|&a| (a as usize) < p),
        "cached assignment has out-of-range partition id"
    );
    let assignment = EdgeAssignment { num_partitions: p, assignment: assignment_vec };
    // Partitions.
    let n_parts = read_u64(&mut r)? as usize;
    ensure!(n_parts == p, "cached file holds {n_parts} partitions, want {p}");
    let mut parts = Vec::with_capacity(n_parts);
    for i in 0..n_parts {
        let id = read_u64(&mut r)? as usize;
        ensure!(id == i, "cached partitions out of order: slot {i} holds id {id}");
        let n_vert = read_u64(&mut r)? as usize;
        let n_core = read_u64(&mut r)? as usize;
        let n_supp = read_u64(&mut r)? as usize;
        ensure!(
            n_vert <= g.num_entities && n_core + n_supp <= g.train.len(),
            "cached partition {i} is larger than the graph"
        );
        let vertices = read_u32s(&mut r, n_vert)?;
        let mut role_tags = vec![0u8; n_vert];
        r.read_exact(&mut role_tags)?;
        let roles = role_tags.iter().map(|&t| role_from_tag(t)).collect::<Result<Vec<_>>>()?;
        let core_edges = read_triples(&mut r, n_core)?;
        let support_edges = read_triples(&mut r, n_supp)?;
        parts.push(Partition { id, vertices, roles, core_edges, support_edges });
    }
    // Trailing garbage means the writer and reader disagree — reject.
    let mut trailing = [0u8; 1];
    ensure!(
        r.read(&mut trailing)? == 0,
        "trailing bytes after partition cache payload"
    );
    Ok((assignment, parts))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ExperimentConfig, PartitionStrategy};
    use crate::graph::generator;
    use crate::partition;

    fn graph() -> KnowledgeGraph {
        let mut cfg = ExperimentConfig::tiny().dataset;
        cfg.entities = 400;
        cfg.train_edges = 3000;
        generator::generate(&cfg)
    }

    fn pcfg() -> PartitionConfig {
        PartitionConfig {
            strategy: PartitionStrategy::Hdrf,
            num_partitions: 4,
            hops: 2,
            ..Default::default()
        }
    }

    fn tmp_dir(tag: &str) -> PathBuf {
        let dir =
            std::env::temp_dir().join(format!("kgscale-pcache-{tag}-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn roundtrip_is_deep_equal() {
        let g = graph();
        let cfg = pcfg();
        let assignment = partition::assign_edges(&g, &cfg, 11);
        let parts = partition::expansion::expand(&g, &assignment, cfg.hops);
        let key = cache_key(&g, &cfg, 11);
        let dir = tmp_dir("roundtrip");
        let path = cache_file(&dir, &cfg, key);
        save(&path, key, &cfg, 11, &assignment, &parts).unwrap();
        let (a2, p2) = load(&path, key, &g, &cfg).unwrap();
        assert_eq!(a2, assignment);
        assert_eq!(p2, parts);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn key_is_sensitive_to_graph_config_and_seed() {
        let g = graph();
        let cfg = pcfg();
        let base = cache_key(&g, &cfg, 11);
        assert_eq!(base, cache_key(&g, &cfg, 11), "key must be deterministic");
        assert_ne!(base, cache_key(&g, &cfg, 12), "seed must invalidate");
        let mut c2 = cfg.clone();
        c2.num_partitions = 8;
        assert_ne!(base, cache_key(&g, &c2, 11), "partition count must invalidate");
        let mut c3 = cfg.clone();
        c3.strategy = PartitionStrategy::Random;
        assert_ne!(base, cache_key(&g, &c3, 11), "strategy must invalidate");
        let mut c4 = cfg.clone();
        c4.hops = 1;
        assert_ne!(base, cache_key(&g, &c4, 11), "hops must invalidate");
        let mut g2 = g.clone();
        g2.train[0].r ^= 1;
        assert_ne!(base, cache_key(&g2, &cfg, 11), "train edges must invalidate");
        // build_threads / cache_dir are deliberately NOT part of the key:
        // they change how the build runs, not what it produces.
        let mut c5 = cfg.clone();
        c5.build_threads = 7;
        c5.cache_dir = "elsewhere".into();
        assert_eq!(base, cache_key(&g, &c5, 11));
    }

    #[test]
    fn stale_key_and_garbage_are_rejected() {
        let g = graph();
        let cfg = pcfg();
        let assignment = partition::assign_edges(&g, &cfg, 11);
        let parts = partition::expansion::expand(&g, &assignment, cfg.hops);
        let key = cache_key(&g, &cfg, 11);
        let dir = tmp_dir("stale");
        let path = cache_file(&dir, &cfg, key);
        save(&path, key, &cfg, 11, &assignment, &parts).unwrap();
        // Wrong expected key (e.g. hash of a changed graph) -> stale.
        let err = load(&path, key ^ 1, &g, &cfg).unwrap_err().to_string();
        assert!(err.contains("stale"), "got: {err}");
        // Truncation -> corrupt.
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() / 2]).unwrap();
        assert!(load(&path, key, &g, &cfg).is_err());
        // Plain garbage -> corrupt.
        std::fs::write(&path, b"not a cache file").unwrap();
        assert!(load(&path, key, &g, &cfg).is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }
}

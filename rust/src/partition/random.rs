//! Uniform random edge partitioning — the paper's §4.5.5 baseline.
//! "In Random partitioning, we randomly divide the edges into 4
//! partitions and then subsequently applied neighborhood expansion."
//! Sizes come out balanced, but the RF is maximal, so after expansion
//! every partition is nearly the whole graph (the paper's Table 5
//! Random+NE row: epoch time equal to non-distributed training).

use super::EdgeAssignment;
use crate::graph::KnowledgeGraph;
use crate::util::rng::Rng;

pub fn random(g: &KnowledgeGraph, num_partitions: usize, seed: u64) -> EdgeAssignment {
    let mut rng = Rng::seeded(seed ^ 0xD1CE_BA5E);
    let assignment =
        g.train.iter().map(|_| rng.below(num_partitions) as u32).collect();
    EdgeAssignment { num_partitions, assignment }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExperimentConfig;
    use crate::graph::generator;

    #[test]
    fn random_is_roughly_balanced_and_deterministic() {
        let g = generator::generate(&ExperimentConfig::tiny().dataset);
        let a = random(&g, 4, 1);
        let mut sizes = [0usize; 4];
        for &p in &a.assignment {
            sizes[p as usize] += 1;
        }
        let expect = g.train.len() / 4;
        for &s in &sizes {
            assert!(
                (s as f64 - expect as f64).abs() < expect as f64 * 0.25,
                "random sizes skewed: {sizes:?}"
            );
        }
        assert_eq!(a.assignment, random(&g, 4, 1).assignment);
        assert_ne!(a.assignment, random(&g, 4, 2).assignment);
    }
}

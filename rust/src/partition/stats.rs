//! Partition-quality statistics — the columns of the paper's Table 2 and
//! Table 5: average±std core edges, average±std total edges after
//! neighborhood expansion, and the Replication Factor of Eq. 7:
//!
//!   RF(P_1..P_p) = (1/|V|) · Σ_i |V(E_i)|
//!
//! where V(E_i) is the vertex set touched by partition i's edges
//! (post-expansion).

use super::Partition;
use crate::util::stats::{humanize_count, mean, std};

#[derive(Clone, Debug)]
pub struct PartitionStats {
    pub num_partitions: usize,
    pub core_edges_mean: f64,
    pub core_edges_std: f64,
    pub total_edges_mean: f64,
    pub total_edges_std: f64,
    /// Replication factor over the whole vertex universe (Eq. 7),
    /// post-expansion — the paper's Table 2 "RF" column.
    pub replication_factor: f64,
    /// RF over core edges only (pre-expansion) — the partitioner-quality
    /// signal before expansion can saturate small graphs.
    pub core_replication_factor: f64,
    /// max/min core-edge count — workload-balance indicator (§3.2.1).
    pub balance_ratio: f64,
}

/// Compute Table 2-style statistics for one partitioning run.
/// `num_vertices` is |V| of the original graph.
pub fn compute(parts: &[Partition], num_vertices: usize) -> PartitionStats {
    assert!(!parts.is_empty());
    let core: Vec<f64> = parts.iter().map(|p| p.core_edges.len() as f64).collect();
    let total: Vec<f64> = parts.iter().map(|p| p.total_edges() as f64).collect();
    let vertex_sum: usize = parts.iter().map(|p| p.vertices.len()).sum();
    let core_vertex_sum: usize = parts
        .iter()
        .map(|p| {
            let mut set = std::collections::HashSet::new();
            for e in &p.core_edges {
                set.insert(e.s);
                set.insert(e.t);
            }
            set.len()
        })
        .sum();
    let max_core = core.iter().cloned().fold(f64::MIN, f64::max);
    let min_core = core.iter().cloned().fold(f64::MAX, f64::min).max(1.0);
    PartitionStats {
        num_partitions: parts.len(),
        core_edges_mean: mean(&core),
        core_edges_std: std(&core),
        total_edges_mean: mean(&total),
        total_edges_std: std(&total),
        replication_factor: vertex_sum as f64 / num_vertices as f64,
        core_replication_factor: core_vertex_sum as f64 / num_vertices as f64,
        balance_ratio: max_core / min_core,
    }
}

impl PartitionStats {
    /// "136.0k ± 4.5k" style cell, as in the paper's tables.
    pub fn core_cell(&self) -> String {
        format!("{} ± {}", humanize_count(self.core_edges_mean), humanize_count(self.core_edges_std))
    }

    pub fn total_cell(&self) -> String {
        format!("{} ± {}", humanize_count(self.total_edges_mean), humanize_count(self.total_edges_std))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ExperimentConfig, PartitionConfig, PartitionStrategy};
    use crate::graph::generator;
    use crate::partition;

    fn stats_for(strategy: PartitionStrategy, p: usize) -> PartitionStats {
        let mut dcfg = ExperimentConfig::tiny().dataset;
        dcfg.entities = 800;
        dcfg.train_edges = 6000;
        let g = generator::generate(&dcfg);
        let cfg = PartitionConfig { strategy, num_partitions: p, ..Default::default() };
        let parts = partition::partition_graph(&g, &cfg, 3);
        compute(&parts, g.num_entities)
    }

    #[test]
    fn rf_grows_with_partition_count() {
        let rf2 = stats_for(PartitionStrategy::Hdrf, 2).replication_factor;
        let rf4 = stats_for(PartitionStrategy::Hdrf, 4).replication_factor;
        let rf8 = stats_for(PartitionStrategy::Hdrf, 8).replication_factor;
        assert!(rf2 < rf4 && rf4 < rf8, "RF must grow with P: {rf2:.2} {rf4:.2} {rf8:.2}");
        assert!(rf2 >= 1.0);
    }

    #[test]
    fn random_rf_dominates_hdrf_rf() {
        // Table 5's shape: Random partitions replicate far more vertices.
        // Compare pre-expansion RF — on this tiny dense test graph the
        // 2-hop expansion saturates both to ~the whole graph, which is
        // itself the paper's FB15k-237 observation.
        let hdrf = stats_for(PartitionStrategy::Hdrf, 4);
        let random = stats_for(PartitionStrategy::Random, 4);
        assert!(
            random.core_replication_factor > hdrf.core_replication_factor,
            "random core-RF {:.2} must exceed HDRF core-RF {:.2}",
            random.core_replication_factor,
            hdrf.core_replication_factor
        );
        assert!(random.total_edges_mean >= hdrf.total_edges_mean);
    }

    #[test]
    fn core_mean_is_exact_fraction() {
        let s = stats_for(PartitionStrategy::Hdrf, 4);
        assert!((s.core_edges_mean - 6000.0 / 4.0).abs() < 1e-9);
    }

    #[test]
    fn single_partition_rf_close_to_one() {
        let s = stats_for(PartitionStrategy::Hdrf, 1);
        // One partition: no replication. RF can fall slightly below 1.0
        // because entities whose only edges landed in valid/test splits
        // carry no train edge.
        assert!(s.replication_factor <= 1.0 + 1e-9);
        assert!(s.replication_factor > 0.8);
        assert_eq!(s.num_partitions, 1);
    }

    #[test]
    fn cells_format_like_paper() {
        let s = stats_for(PartitionStrategy::Hdrf, 2);
        assert!(s.core_cell().contains('±'));
        assert!(s.total_cell().contains('±'));
    }
}

//! Neighborhood expansion (paper §3.2.2): make each partition
//! self-sufficient by adding the n-hop dependency closure of its core
//! vertices as *support* vertices and edges, so message passing for any
//! core edge never needs another partition.
//!
//! Semantics (matching the model in `python/compile/model.py`, which adds
//! inverse relations so messages flow along both edge directions):
//!
//! * a vertex at undirected distance `d ≤ n` from a core vertex is needed
//!   (its hidden state h^(n-d) feeds some core embedding);
//! * an edge is needed iff one of its endpoints is at distance `≤ n-1`
//!   (that endpoint still receives messages).
//!
//! Support edges may be core edges *of another partition* — that is the
//! data replication / redundant computation the paper trades for zero
//! communication.

use super::{EdgeAssignment, Partition, VertexRole};
use crate::graph::{Csr, KnowledgeGraph};
use crate::util::pool;

const UNSEEN: u32 = u32::MAX;

/// Reusable per-worker scratch for [`expand_one`] — the same stamped
/// arena trick as `ComputeGraphBuilder`'s stamp arrays: the O(N) vertex
/// and O(E) edge state is allocated **once per worker** and logically
/// cleared in O(1) by bumping `stamp`, instead of re-allocating (and
/// re-zeroing) `dist`/`needed_edges` vectors for every partition.
pub struct ExpansionScratch {
    stamp: u32,
    /// `dist[v]` is valid iff `dist_stamp[v] == stamp`; else UNSEEN.
    dist_stamp: Vec<u32>,
    dist: Vec<u32>,
    /// Train edge `eid` is needed iff `edge_stamp[eid] == stamp`.
    edge_stamp: Vec<u32>,
    /// BFS frontier double buffer, reused across partitions.
    frontier_a: Vec<u32>,
    frontier_b: Vec<u32>,
}

impl ExpansionScratch {
    pub fn new(num_entities: usize, num_train_edges: usize) -> ExpansionScratch {
        ExpansionScratch {
            stamp: 0,
            dist_stamp: vec![0; num_entities],
            dist: vec![0; num_entities],
            edge_stamp: vec![0; num_train_edges],
            frontier_a: Vec::new(),
            frontier_b: Vec::new(),
        }
    }

    /// Start a fresh expansion: O(1) except on u32 wraparound, where the
    /// stamp arrays are hard-reset so a stale stamp can never collide.
    fn begin(&mut self) {
        if self.stamp == u32::MAX {
            self.dist_stamp.iter_mut().for_each(|s| *s = 0);
            self.edge_stamp.iter_mut().for_each(|s| *s = 0);
            self.stamp = 0;
        }
        self.stamp += 1;
    }

    #[inline]
    fn dist(&self, v: u32) -> u32 {
        if self.dist_stamp[v as usize] == self.stamp { self.dist[v as usize] } else { UNSEEN }
    }

    #[inline]
    fn set_dist(&mut self, v: u32, d: u32) {
        self.dist_stamp[v as usize] = self.stamp;
        self.dist[v as usize] = d;
    }

    #[inline]
    fn mark_edge(&mut self, eid: u32) {
        self.edge_stamp[eid as usize] = self.stamp;
    }

    #[inline]
    fn edge_needed(&self, eid: usize) -> bool {
        self.edge_stamp[eid] == self.stamp
    }
}

/// Expand every partition of `assignment` to `hops`-hop self-sufficiency.
///
/// Sequential reference entry point: builds its own CSR and runs the
/// partitions in order on this thread. `partition::build_partitions`
/// shares one CSR across assignment + expansion and fans out on worker
/// threads instead — see [`expand_with`].
pub fn expand(g: &KnowledgeGraph, assignment: &EdgeAssignment, hops: usize) -> Vec<Partition> {
    let csr = Csr::build(g.num_entities, &g.train);
    expand_with(g, &csr, assignment, hops, 0)
}

/// Expand with a caller-provided CSR, fanning `expand_one` out across
/// `build_threads` workers (0 = sequential reference path). Results are
/// collected in fixed partition order and each worker reuses one
/// [`ExpansionScratch`] across every partition it claims, so the output
/// is **bit-identical** for any thread count (pinned by test).
pub fn expand_with(
    g: &KnowledgeGraph,
    csr: &Csr,
    assignment: &EdgeAssignment,
    hops: usize,
    build_threads: usize,
) -> Vec<Partition> {
    assert_eq!(assignment.assignment.len(), g.train.len());
    let p = assignment.num_partitions;
    let core_part_count = count_core_parts(g, assignment);

    if build_threads == 0 || p <= 1 {
        let mut scratch = ExpansionScratch::new(g.num_entities, g.train.len());
        return (0..p)
            .map(|part| expand_one(g, csr, assignment, part, hops, &core_part_count, &mut scratch))
            .collect();
    }

    let cpc = &core_part_count;
    pool::scoped_map(
        build_threads.min(p),
        p,
        || ExpansionScratch::new(g.num_entities, g.train.len()),
        move |scratch, part| expand_one(g, csr, assignment, part, hops, cpc, scratch),
    )
}

/// How many partitions hold each vertex as a core endpoint — needed to
/// distinguish Core from Replicated roles. One bitset pass: exact by
/// construction (a vertex-partition bit is set at most once however many
/// core edges repeat the pair).
fn count_core_parts(g: &KnowledgeGraph, assignment: &EdgeAssignment) -> Vec<u32> {
    let words = assignment.num_partitions.div_ceil(64);
    let mut bits = vec![0u64; g.num_entities * words];
    for (eid, e) in g.train.iter().enumerate() {
        let part = assignment.assignment[eid] as usize;
        for v in [e.s as usize, e.t as usize] {
            bits[v * words + part / 64] |= 1 << (part % 64);
        }
    }
    (0..g.num_entities)
        .map(|v| bits[v * words..(v + 1) * words].iter().map(|w| w.count_ones()).sum())
        .collect()
}

fn expand_one(
    g: &KnowledgeGraph,
    csr: &Csr,
    assignment: &EdgeAssignment,
    part: usize,
    hops: usize,
    core_part_count: &[u32],
    scratch: &mut ExpansionScratch,
) -> Partition {
    scratch.begin();
    let mut core_edges = Vec::new();
    let mut vertices: Vec<u32> = Vec::new();

    // Distance-0 layer: endpoints of this partition's core edges.
    for (eid, e) in g.train.iter().enumerate() {
        if assignment.assignment[eid] as usize == part {
            core_edges.push(*e);
            for v in [e.s, e.t] {
                if scratch.dist(v) == UNSEEN {
                    scratch.set_dist(v, 0);
                    vertices.push(v);
                }
            }
        }
    }

    // BFS out to `hops`, collecting needed edges: an edge is needed when
    // first touched from an endpoint at distance <= hops-1. The frontier
    // buffers are borrowed out of the scratch so the loop below can
    // mutate stamps while iterating the current layer.
    let mut current = std::mem::take(&mut scratch.frontier_a);
    let mut next = std::mem::take(&mut scratch.frontier_b);
    current.clear();
    current.extend_from_slice(&vertices);
    for d in 0..hops as u32 {
        next.clear();
        for &v in &current {
            debug_assert_eq!(scratch.dist(v), d);
            for eid in csr.incident(v) {
                scratch.mark_edge(eid);
                let e = g.train[eid as usize];
                let w = if e.s == v { e.t } else { e.s };
                if scratch.dist(w) == UNSEEN {
                    scratch.set_dist(w, d + 1);
                    next.push(w);
                    vertices.push(w);
                }
            }
        }
        std::mem::swap(&mut current, &mut next);
    }
    scratch.frontier_a = current;
    scratch.frontier_b = next;

    // Support edges: needed but not core-of-this-partition.
    let mut support_edges = Vec::new();
    for (eid, &owner) in assignment.assignment.iter().enumerate() {
        if scratch.edge_needed(eid) && owner as usize != part {
            support_edges.push(g.train[eid]);
        }
    }

    vertices.sort_unstable();
    let roles = vertices
        .iter()
        .map(|&v| {
            if scratch.dist(v) == 0 {
                if core_part_count[v as usize] > 1 {
                    VertexRole::Replicated
                } else {
                    VertexRole::Core
                }
            } else {
                VertexRole::Support
            }
        })
        .collect();

    Partition { id: part, vertices, roles, core_edges, support_edges }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ExperimentConfig, PartitionConfig, PartitionStrategy};
    use crate::graph::{generator, Triple};
    use crate::partition;
    use std::collections::HashSet;

    fn graph() -> KnowledgeGraph {
        let mut cfg = ExperimentConfig::tiny().dataset;
        cfg.entities = 400;
        cfg.train_edges = 3000;
        generator::generate(&cfg)
    }

    fn parts(hops: usize) -> (KnowledgeGraph, Vec<Partition>) {
        let g = graph();
        let cfg = PartitionConfig {
            strategy: PartitionStrategy::Hdrf,
            num_partitions: 4,
            hops,
            ..Default::default()
        };
        let ps = partition::partition_graph(&g, &cfg, 11);
        (g, ps)
    }

    /// The paper's self-sufficiency invariant: every vertex within
    /// distance < hops of a core vertex has ALL its incident edges in the
    /// partition (so its message aggregation is complete locally).
    #[test]
    fn expansion_is_self_sufficient() {
        let (g, ps) = parts(2);
        let csr = Csr::build(g.num_entities, &g.train);
        for p in &ps {
            let edge_set: HashSet<u64> =
                p.core_edges.iter().chain(&p.support_edges).map(Triple::key).collect();
            // Recompute distances within the partition's own BFS.
            let mut dist = std::collections::HashMap::new();
            for e in &p.core_edges {
                dist.insert(e.s, 0u32);
                dist.insert(e.t, 0u32);
            }
            let mut frontier: Vec<u32> = dist.keys().copied().collect();
            for d in 0..1u32 {
                // need full edges for vertices at distance <= hops-1 = 1
                let mut next = Vec::new();
                for &v in &frontier {
                    for &eid in csr.out_edges(v).iter().chain(csr.in_edges(v)) {
                        let e = g.train[eid as usize];
                        let w = if e.s == v { e.t } else { e.s };
                        if !dist.contains_key(&w) {
                            dist.insert(w, d + 1);
                            next.push(w);
                        }
                    }
                }
                frontier = next;
            }
            for (&v, &d) in &dist {
                if d <= 1 {
                    for &eid in csr.out_edges(v).iter().chain(csr.in_edges(v)) {
                        let e = g.train[eid as usize];
                        assert!(
                            edge_set.contains(&e.key()),
                            "partition {} missing edge {e:?} incident to dist-{d} vertex {v}",
                            p.id
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn all_edge_endpoints_are_in_vertex_set() {
        let (_, ps) = parts(2);
        for p in &ps {
            for e in p.core_edges.iter().chain(&p.support_edges) {
                assert!(p.local_of(e.s).is_some(), "missing endpoint {}", e.s);
                assert!(p.local_of(e.t).is_some(), "missing endpoint {}", e.t);
            }
        }
    }

    #[test]
    fn roles_are_consistent() {
        let (_, ps) = parts(2);
        // Count, per vertex, the partitions where it has role Core/Replicated.
        let mut count: std::collections::HashMap<u32, u32> = Default::default();
        for p in &ps {
            for (v, role) in p.vertices.iter().zip(&p.roles) {
                if !matches!(role, VertexRole::Support) {
                    *count.entry(*v).or_default() += 1;
                }
            }
        }
        for p in &ps {
            for (v, role) in p.vertices.iter().zip(&p.roles) {
                match role {
                    VertexRole::Core => assert_eq!(count[v], 1, "Core vertex {v} in >1 partition"),
                    VertexRole::Replicated => {
                        assert!(count[v] > 1, "Replicated vertex {v} in only one partition")
                    }
                    VertexRole::Support => {}
                }
            }
        }
    }

    #[test]
    fn support_edges_disjoint_from_core() {
        let (_, ps) = parts(2);
        for p in &ps {
            let core: HashSet<u64> = p.core_edges.iter().map(Triple::key).collect();
            for e in &p.support_edges {
                assert!(!core.contains(&e.key()));
            }
        }
    }

    #[test]
    fn more_hops_means_no_smaller_partitions() {
        let g = graph();
        for strategy in [PartitionStrategy::Hdrf, PartitionStrategy::Random] {
            let mk = |hops| {
                let cfg =
                    PartitionConfig { strategy, num_partitions: 4, hops, ..Default::default() };
                partition::partition_graph(&g, &cfg, 11)
            };
            let one = mk(1);
            let two = mk(2);
            for (a, b) in one.iter().zip(&two) {
                assert!(b.total_edges() >= a.total_edges());
                assert!(b.vertices.len() >= a.vertices.len());
            }
        }
    }

    #[test]
    fn zero_hops_adds_nothing() {
        let g = graph();
        let a = partition::assign_edges(
            &g,
            &PartitionConfig {
                strategy: PartitionStrategy::Hdrf,
                num_partitions: 4,
                hops: 2,
                ..Default::default()
            },
            11,
        );
        let ps = expand(&g, &a, 0);
        for p in &ps {
            assert!(p.support_edges.is_empty());
            assert!(p.roles.iter().all(|r| !matches!(r, VertexRole::Support)));
        }
    }

    /// Tentpole invariant: threaded expansion (any worker count, each
    /// worker's scratch reused across the partitions it claims) is
    /// bit-identical to the sequential `build_threads = 0` reference —
    /// vertices, roles, core/support edges, and their order.
    #[test]
    fn threaded_expansion_bit_identical_to_sequential() {
        let g = graph();
        for strategy in [
            PartitionStrategy::Hdrf,
            PartitionStrategy::Random,
            PartitionStrategy::MetisLike,
        ] {
            for hops in [1usize, 2] {
                let cfg =
                    PartitionConfig { strategy, num_partitions: 4, hops, ..Default::default() };
                let a = partition::assign_edges(&g, &cfg, 11);
                let csr = Csr::build(g.num_entities, &g.train);
                let want = expand_with(&g, &csr, &a, hops, 0);
                for threads in [1usize, 2, 3, 8] {
                    let got = expand_with(&g, &csr, &a, hops, threads);
                    assert_eq!(got, want, "{strategy:?} hops={hops} threads={threads}");
                }
            }
        }
    }

    /// A shared scratch reused across partitions (and re-used for a
    /// partition it already expanded) yields exactly what fresh
    /// per-partition scratch does — the stamp bump really isolates runs.
    #[test]
    fn scratch_reuse_is_stateless_across_partitions() {
        let g = graph();
        let cfg = PartitionConfig {
            strategy: PartitionStrategy::Hdrf,
            num_partitions: 4,
            hops: 2,
            ..Default::default()
        };
        let a = partition::assign_edges(&g, &cfg, 11);
        let csr = Csr::build(g.num_entities, &g.train);
        let cpc = count_core_parts(&g, &a);
        let fresh: Vec<Partition> = (0..4)
            .map(|part| {
                let mut s = ExpansionScratch::new(g.num_entities, g.train.len());
                expand_one(&g, &csr, &a, part, 2, &cpc, &mut s)
            })
            .collect();
        let mut shared = ExpansionScratch::new(g.num_entities, g.train.len());
        for (part, want) in fresh.iter().enumerate() {
            let got = expand_one(&g, &csr, &a, part, 2, &cpc, &mut shared);
            assert_eq!(&got, want, "shared scratch diverged at partition {part}");
        }
        let again = expand_one(&g, &csr, &a, 0, 2, &cpc, &mut shared);
        assert_eq!(&again, &fresh[0], "re-expansion on a dirty scratch diverged");
    }

    /// Stamp wraparound hard-resets the arena instead of colliding with
    /// stale entries from 2^32 expansions ago.
    #[test]
    fn stamp_wraparound_resets_cleanly() {
        let g = graph();
        let cfg = PartitionConfig {
            strategy: PartitionStrategy::Hdrf,
            num_partitions: 2,
            hops: 2,
            ..Default::default()
        };
        let a = partition::assign_edges(&g, &cfg, 11);
        let csr = Csr::build(g.num_entities, &g.train);
        let cpc = count_core_parts(&g, &a);
        let mut s = ExpansionScratch::new(g.num_entities, g.train.len());
        let want0 = expand_one(&g, &csr, &a, 0, 2, &cpc, &mut s);
        let want1 = expand_one(&g, &csr, &a, 1, 2, &cpc, &mut s);
        s.stamp = u32::MAX - 1; // next begin() lands on MAX, then wraps
        assert_eq!(expand_one(&g, &csr, &a, 0, 2, &cpc, &mut s), want0);
        assert_eq!(expand_one(&g, &csr, &a, 1, 2, &cpc, &mut s), want1);
        assert_eq!(expand_one(&g, &csr, &a, 0, 2, &cpc, &mut s), want0);
    }
}

//! Neighborhood expansion (paper §3.2.2): make each partition
//! self-sufficient by adding the n-hop dependency closure of its core
//! vertices as *support* vertices and edges, so message passing for any
//! core edge never needs another partition.
//!
//! Semantics (matching the model in `python/compile/model.py`, which adds
//! inverse relations so messages flow along both edge directions):
//!
//! * a vertex at undirected distance `d ≤ n` from a core vertex is needed
//!   (its hidden state h^(n-d) feeds some core embedding);
//! * an edge is needed iff one of its endpoints is at distance `≤ n-1`
//!   (that endpoint still receives messages).
//!
//! Support edges may be core edges *of another partition* — that is the
//! data replication / redundant computation the paper trades for zero
//! communication.

use super::{EdgeAssignment, Partition, VertexRole};
use crate::graph::{Csr, KnowledgeGraph};

/// Expand every partition of `assignment` to `hops`-hop self-sufficiency.
pub fn expand(g: &KnowledgeGraph, assignment: &EdgeAssignment, hops: usize) -> Vec<Partition> {
    assert_eq!(assignment.assignment.len(), g.train.len());
    let p = assignment.num_partitions;
    let csr = Csr::build(g.num_entities, &g.train);

    // How many partitions hold each vertex as a core endpoint — needed to
    // distinguish Core from Replicated roles.
    let mut core_part_count = vec![0u32; g.num_entities];
    {
        let mut last_seen = vec![u32::MAX; g.num_entities];
        for (eid, e) in g.train.iter().enumerate() {
            let part = assignment.assignment[eid];
            for v in [e.s, e.t] {
                if last_seen[v as usize] != part {
                    last_seen[v as usize] = part;
                    core_part_count[v as usize] += 1;
                }
            }
        }
        // last_seen dedupes consecutive hits only; recompute exactly with
        // a bitset pass when P is small enough to matter. Simpler: exact
        // recount below.
        core_part_count.iter_mut().for_each(|c| *c = 0);
        let words = p.div_ceil(64);
        let mut bits = vec![0u64; g.num_entities * words];
        for (eid, e) in g.train.iter().enumerate() {
            let part = assignment.assignment[eid] as usize;
            for v in [e.s as usize, e.t as usize] {
                bits[v * words + part / 64] |= 1 << (part % 64);
            }
        }
        for v in 0..g.num_entities {
            core_part_count[v] =
                bits[v * words..(v + 1) * words].iter().map(|w| w.count_ones()).sum();
        }
    }

    (0..p).map(|part| expand_one(g, &csr, assignment, part, hops, &core_part_count)).collect()
}

fn expand_one(
    g: &KnowledgeGraph,
    csr: &Csr,
    assignment: &EdgeAssignment,
    part: usize,
    hops: usize,
    core_part_count: &[u32],
) -> Partition {
    const UNSEEN: u32 = u32::MAX;
    let mut dist = vec![UNSEEN; g.num_entities];
    let mut frontier: Vec<u32> = Vec::new();
    let mut core_edges = Vec::new();

    // Distance-0 layer: endpoints of this partition's core edges.
    for (eid, e) in g.train.iter().enumerate() {
        if assignment.assignment[eid] as usize == part {
            core_edges.push(*e);
            for v in [e.s, e.t] {
                if dist[v as usize] == UNSEEN {
                    dist[v as usize] = 0;
                    frontier.push(v);
                }
            }
        }
    }

    // BFS out to `hops`, collecting needed edges: an edge is needed when
    // first touched from an endpoint at distance <= hops-1.
    let mut needed_edges: Vec<bool> = vec![false; g.train.len()];
    let mut vertices: Vec<u32> = frontier.clone();
    let mut current = frontier;
    for d in 0..hops as u32 {
        let mut next: Vec<u32> = Vec::new();
        for &v in &current {
            debug_assert_eq!(dist[v as usize], d);
            for &eid in csr.out_edges(v).iter().chain(csr.in_edges(v)) {
                needed_edges[eid as usize] = true;
                let e = g.train[eid as usize];
                let w = if e.s == v { e.t } else { e.s };
                if dist[w as usize] == UNSEEN {
                    dist[w as usize] = d + 1;
                    next.push(w);
                    vertices.push(w);
                }
            }
        }
        current = next;
    }

    // Support edges: needed but not core-of-this-partition.
    let mut support_edges = Vec::new();
    for (eid, &needed) in needed_edges.iter().enumerate() {
        if needed && assignment.assignment[eid] as usize != part {
            support_edges.push(g.train[eid]);
        }
    }

    vertices.sort_unstable();
    let roles = vertices
        .iter()
        .map(|&v| {
            if dist[v as usize] == 0 {
                if core_part_count[v as usize] > 1 {
                    VertexRole::Replicated
                } else {
                    VertexRole::Core
                }
            } else {
                VertexRole::Support
            }
        })
        .collect();

    Partition { id: part, vertices, roles, core_edges, support_edges }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ExperimentConfig, PartitionConfig, PartitionStrategy};
    use crate::graph::{generator, Triple};
    use crate::partition;
    use std::collections::HashSet;

    fn graph() -> KnowledgeGraph {
        let mut cfg = ExperimentConfig::tiny().dataset;
        cfg.entities = 400;
        cfg.train_edges = 3000;
        generator::generate(&cfg)
    }

    fn parts(hops: usize) -> (KnowledgeGraph, Vec<Partition>) {
        let g = graph();
        let cfg = PartitionConfig {
            strategy: PartitionStrategy::Hdrf,
            num_partitions: 4,
            hops,
            hdrf_lambda: 1.0,
        };
        let ps = partition::partition_graph(&g, &cfg, 11);
        (g, ps)
    }

    /// The paper's self-sufficiency invariant: every vertex within
    /// distance < hops of a core vertex has ALL its incident edges in the
    /// partition (so its message aggregation is complete locally).
    #[test]
    fn expansion_is_self_sufficient() {
        let (g, ps) = parts(2);
        let csr = Csr::build(g.num_entities, &g.train);
        for p in &ps {
            let edge_set: HashSet<u64> =
                p.core_edges.iter().chain(&p.support_edges).map(Triple::key).collect();
            // Recompute distances within the partition's own BFS.
            let mut dist = std::collections::HashMap::new();
            for e in &p.core_edges {
                dist.insert(e.s, 0u32);
                dist.insert(e.t, 0u32);
            }
            let mut frontier: Vec<u32> = dist.keys().copied().collect();
            for d in 0..1u32 {
                // need full edges for vertices at distance <= hops-1 = 1
                let mut next = Vec::new();
                for &v in &frontier {
                    for &eid in csr.out_edges(v).iter().chain(csr.in_edges(v)) {
                        let e = g.train[eid as usize];
                        let w = if e.s == v { e.t } else { e.s };
                        if !dist.contains_key(&w) {
                            dist.insert(w, d + 1);
                            next.push(w);
                        }
                    }
                }
                frontier = next;
            }
            for (&v, &d) in &dist {
                if d <= 1 {
                    for &eid in csr.out_edges(v).iter().chain(csr.in_edges(v)) {
                        let e = g.train[eid as usize];
                        assert!(
                            edge_set.contains(&e.key()),
                            "partition {} missing edge {e:?} incident to dist-{d} vertex {v}",
                            p.id
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn all_edge_endpoints_are_in_vertex_set() {
        let (_, ps) = parts(2);
        for p in &ps {
            for e in p.core_edges.iter().chain(&p.support_edges) {
                assert!(p.local_of(e.s).is_some(), "missing endpoint {}", e.s);
                assert!(p.local_of(e.t).is_some(), "missing endpoint {}", e.t);
            }
        }
    }

    #[test]
    fn roles_are_consistent() {
        let (_, ps) = parts(2);
        // Count, per vertex, the partitions where it has role Core/Replicated.
        let mut count: std::collections::HashMap<u32, u32> = Default::default();
        for p in &ps {
            for (v, role) in p.vertices.iter().zip(&p.roles) {
                if !matches!(role, VertexRole::Support) {
                    *count.entry(*v).or_default() += 1;
                }
            }
        }
        for p in &ps {
            for (v, role) in p.vertices.iter().zip(&p.roles) {
                match role {
                    VertexRole::Core => assert_eq!(count[v], 1, "Core vertex {v} in >1 partition"),
                    VertexRole::Replicated => {
                        assert!(count[v] > 1, "Replicated vertex {v} in only one partition")
                    }
                    VertexRole::Support => {}
                }
            }
        }
    }

    #[test]
    fn support_edges_disjoint_from_core() {
        let (_, ps) = parts(2);
        for p in &ps {
            let core: HashSet<u64> = p.core_edges.iter().map(Triple::key).collect();
            for e in &p.support_edges {
                assert!(!core.contains(&e.key()));
            }
        }
    }

    #[test]
    fn more_hops_means_no_smaller_partitions() {
        let g = graph();
        for strategy in [PartitionStrategy::Hdrf, PartitionStrategy::Random] {
            let mk = |hops| {
                let cfg = PartitionConfig { strategy, num_partitions: 4, hops, hdrf_lambda: 1.0 };
                partition::partition_graph(&g, &cfg, 11)
            };
            let one = mk(1);
            let two = mk(2);
            for (a, b) in one.iter().zip(&two) {
                assert!(b.total_edges() >= a.total_edges());
                assert!(b.vertices.len() >= a.vertices.len());
            }
        }
    }

    #[test]
    fn zero_hops_adds_nothing() {
        let g = graph();
        let a = partition::assign_edges(
            &g,
            &PartitionConfig {
                strategy: PartitionStrategy::Hdrf,
                num_partitions: 4,
                hops: 2,
                hdrf_lambda: 1.0,
            },
            11,
        );
        let ps = expand(&g, &a, 0);
        for p in &ps {
            assert!(p.support_edges.is_empty());
            assert!(p.roles.iter().all(|r| !matches!(r, VertexRole::Support)));
        }
    }
}

//! Experiment configuration: a TOML-subset file (`configs/*.toml`) parsed
//! into typed structs with validated defaults. Every run of the system —
//! CLI, examples, benches, tests — goes through [`ExperimentConfig`], so
//! a config file fully determines a reproducible experiment.

pub mod toml;

use crate::util::json::Json;
use anyhow::{bail, Context, Result};

/// What kind of synthetic graph to generate (see `graph::generator`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DatasetKind {
    /// Multi-relational KG with Zipf-skewed entity popularity
    /// (FB15k-237 stand-in).
    ZipfKg,
    /// Single-relation citation-style graph grown by preferential
    /// attachment, with dense input features (ogbl-citation2 stand-in).
    Citation,
}

impl DatasetKind {
    pub fn from_str(s: &str) -> Result<Self> {
        match s {
            "zipf_kg" => Ok(DatasetKind::ZipfKg),
            "citation" => Ok(DatasetKind::Citation),
            other => bail!("unknown dataset kind {other:?} (want zipf_kg|citation)"),
        }
    }
}

#[derive(Clone, Debug)]
pub struct DatasetConfig {
    pub name: String,
    pub kind: DatasetKind,
    pub entities: usize,
    pub relations: usize,
    pub train_edges: usize,
    pub valid_edges: usize,
    pub test_edges: usize,
    /// 0 ⇒ featureless (trainable embedding table); >0 ⇒ provided features.
    pub feature_dim: usize,
    /// Skew of entity popularity for ZipfKg / attachment bias strength.
    pub zipf_exponent: f64,
    pub seed: u64,
}

#[derive(Clone, Debug)]
pub struct ModelConfig {
    /// Hidden & output embedding dimension d.
    pub embed_dim: usize,
    /// Number of basis matrices B in the basis decomposition (Eq. 2).
    pub num_bases: usize,
    /// Number of RGCN layers = message-passing hops n.
    pub num_layers: usize,
    pub dropout: f64,
    /// Add inverse relations (r+R) so messages flow both directions —
    /// standard RGCN link-prediction setup.
    pub inverse_relations: bool,
    pub self_loop: bool,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GradSync {
    /// Ring AllReduce (the paper's choice, §2.2/§3.1).
    Ring,
    /// Parameter-server baseline (§2.2 comparison).
    ParamServer,
    /// Sparse all-gather (DGL-KE-style): workers exchange only the
    /// touched embedding rows + the dense tail, so sync bytes scale with
    /// the batch's compute graph, not param_count. Requires a sparse
    /// gradient mode (validated).
    Sparse,
    /// No sync — each worker drifts; used only in ablations/tests.
    None,
}

impl GradSync {
    pub fn from_str(s: &str) -> Result<Self> {
        match s {
            "ring" => Ok(GradSync::Ring),
            "param_server" => Ok(GradSync::ParamServer),
            "sparse" => Ok(GradSync::Sparse),
            "none" => Ok(GradSync::None),
            other => bail!("unknown grad_sync {other:?} (want ring|param_server|sparse|none)"),
        }
    }
}

/// How gradients are accumulated and applied each synchronous step.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GradMode {
    /// Reference path: dense accumulator, dense Adam. O(param_count) per
    /// step.
    Dense,
    /// Row-sparse accumulation keyed off the compute graph's touched
    /// entity rows, then dense Adam over the scattered gradient —
    /// bit-identical results to `Dense`, with O(touched) accumulate/zero
    /// and sparse-sized sync traffic.
    Sparse,
    /// Row-sparse accumulation + lazy Adam (DGL-KE style): optimizer
    /// moments and parameters update only at touched rows. O(touched)
    /// end to end; not bit-equivalent to `Dense` (documented deviation
    /// in `train::optimizer`).
    SparseLazy,
}

impl GradMode {
    pub fn from_str(s: &str) -> Result<Self> {
        match s {
            "dense" => Ok(GradMode::Dense),
            "sparse" => Ok(GradMode::Sparse),
            "sparse_lazy" => Ok(GradMode::SparseLazy),
            other => bail!("unknown grad_mode {other:?} (want dense|sparse|sparse_lazy)"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            GradMode::Dense => "dense",
            GradMode::Sparse => "sparse",
            GradMode::SparseLazy => "sparse_lazy",
        }
    }

    /// Stable on-disk tag (checkpoint header).
    pub fn as_u32(&self) -> u32 {
        match self {
            GradMode::Dense => 0,
            GradMode::Sparse => 1,
            GradMode::SparseLazy => 2,
        }
    }

    pub fn from_u32(v: u32) -> Result<Self> {
        match v {
            0 => Ok(GradMode::Dense),
            1 => Ok(GradMode::Sparse),
            2 => Ok(GradMode::SparseLazy),
            other => bail!("unknown grad_mode tag {other}"),
        }
    }
}

#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub lr: f64,
    pub adam_beta1: f64,
    pub adam_beta2: f64,
    pub adam_eps: f64,
    pub epochs: usize,
    /// Positive edges per mini-batch; 0 ⇒ full-batch (all core edges).
    pub batch_edges: usize,
    /// s in the paper: negatives sampled per positive.
    pub negatives_per_positive: usize,
    pub num_trainers: usize,
    pub grad_sync: GradSync,
    /// Gradient accumulation/optimizer path; `dense` preserves the
    /// original semantics exactly.
    pub grad_mode: GradMode,
    /// Negative sampling scope: true = constraint-based/local (paper),
    /// false = global baseline (ablation; models cross-partition fetches).
    pub local_negatives: bool,
    pub seed: u64,
    /// Evaluate on validation every k epochs (0 = only at end).
    pub eval_every: usize,
    /// Host prep threads for the pipelined data path (`train::pipeline`):
    /// pool threads build compute graphs and fill padded inputs for
    /// upcoming steps while the coordinator executes XLA. 0 = sequential
    /// reference path. Results are bit-identical either way.
    pub host_threads: usize,
    /// How many steps ahead of execution a worker's batch prep may run
    /// (bounds buffered batches per worker). Must be >= 1; only takes
    /// effect with `host_threads > 0`.
    pub prefetch_depth: usize,
    /// Atomic checkpoint cadence: snapshot params + Adam state every k
    /// epoch boundaries (`train::checkpoint` v3, tmp + rename +
    /// checksum). 0 = checkpointing off. Required (>0) when
    /// `faults.crash_rate > 0` — crash recovery restores from the last
    /// snapshot.
    pub checkpoint_every_epochs: usize,
    /// Directory for `ckpt-NNNNNN.ckpt` snapshots; must be non-empty
    /// when `checkpoint_every_epochs > 0`. Also the target of
    /// `kgscale train --resume <dir>`.
    pub checkpoint_dir: String,
    /// Retention: keep the newest K snapshots, prune the rest (>= 1).
    pub checkpoint_keep: usize,
}

/// Deterministic fault injection on the simulated cluster
/// (`train::faults`). Disabled by default; when `enabled`, a seeded
/// `FaultPlan` schedules worker crashes, straggler slowdowns, and
/// transient sync-link degradation per epoch, fully reproducible from
/// `seed`. With `enabled = false` the trainer takes the exact
/// pre-fault-layer code path (bit-identical results, pinned by test).
#[derive(Clone, Debug)]
pub struct FaultsConfig {
    pub enabled: bool,
    /// Seed of the fault schedule stream — independent of `train.seed`,
    /// so faults never perturb sampling/init RNG.
    pub seed: u64,
    /// Per (step, worker) Bernoulli probability of a crash; at most one
    /// crash is scheduled per epoch (the first success). In [0, 1].
    pub crash_rate: f64,
    /// Per (epoch, worker) probability of a straggler window. In [0, 1].
    pub straggler_rate: f64,
    /// Compute-time multiplier inside a straggler window (>= 1).
    pub slowdown_factor: f64,
    /// Straggler window length in steps (clamped to the epoch).
    pub straggler_steps: usize,
    /// Per-epoch probability of a sync-link degradation window. In [0, 1].
    pub link_degrade_rate: f64,
    /// Multiplier on modeled α/β sync cost inside the window (>= 1).
    pub link_degrade_factor: f64,
    /// Link-degradation window length in steps (clamped to the epoch).
    pub link_degrade_steps: usize,
    /// Virtual seconds the synchronous barrier takes to declare a
    /// replica dead (failure-detector timeout) before recovery starts.
    pub detect_secs: f64,
}

impl Default for FaultsConfig {
    fn default() -> Self {
        FaultsConfig {
            enabled: false,
            seed: 0xFA17,
            crash_rate: 0.0,
            straggler_rate: 0.0,
            slowdown_factor: 4.0,
            straggler_steps: 8,
            link_degrade_rate: 0.0,
            link_degrade_factor: 4.0,
            link_degrade_steps: 8,
            detect_secs: 1.0,
        }
    }
}

/// Evaluation-path knobs (`eval::pipeline`), symmetric with the train
/// pipeline's `host_threads`/`prefetch_depth` pair.
#[derive(Clone, Debug)]
pub struct EvalConfig {
    /// Host threads computing filtered ranks while the coordinator
    /// executes the next score chunk. 0 = sequential reference path.
    /// MRR/Hits@k are bit-identical either way.
    pub host_threads: usize,
    /// Score-readback slots rotated by the overlapped path (1 = no
    /// lookahead, 2 = double buffering). Must be >= 1; only takes
    /// effect with `host_threads > 0`.
    pub prefetch_depth: usize,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PartitionStrategy {
    /// High-Degree Replicated First streaming vertex-cut (KaHIP-substitute).
    Hdrf,
    /// Degree-Based Hashing vertex-cut (cheap baseline).
    Dbh,
    /// Greedy vertex partitioning + 1-hop core edges (METIS-substitute).
    MetisLike,
    /// Uniform random edge assignment (paper's Random baseline).
    Random,
}

impl PartitionStrategy {
    pub fn from_str(s: &str) -> Result<Self> {
        match s {
            "hdrf" => Ok(PartitionStrategy::Hdrf),
            "dbh" => Ok(PartitionStrategy::Dbh),
            "metis_like" => Ok(PartitionStrategy::MetisLike),
            "random" => Ok(PartitionStrategy::Random),
            other => bail!("unknown partition strategy {other:?}"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            PartitionStrategy::Hdrf => "hdrf",
            PartitionStrategy::Dbh => "dbh",
            PartitionStrategy::MetisLike => "metis_like",
            PartitionStrategy::Random => "random",
        }
    }
}

#[derive(Clone, Debug)]
pub struct PartitionConfig {
    pub strategy: PartitionStrategy,
    pub num_partitions: usize,
    /// Neighborhood-expansion hops; must equal `model.num_layers` for
    /// self-sufficiency (validated below).
    pub hops: usize,
    /// HDRF balance/replication trade-off parameter λ.
    pub hdrf_lambda: f64,
    /// Worker threads for neighborhood expansion: partitions expand in
    /// parallel, each worker reusing one arena scratch. 0 = sequential
    /// reference path. Output is bit-identical for any value.
    pub build_threads: usize,
    /// Directory for the on-disk partition cache; "" disables caching.
    /// Entries are keyed by a content hash of the graph (entity/relation
    /// counts + every train-edge triple), the partition config
    /// (strategy, num_partitions, hops, hdrf_lambda), and the dataset
    /// seed — change any of those and the cache invalidates itself. A
    /// stale or corrupt entry is rebuilt with a logged warning, never an
    /// error. `build_threads` and `cache_dir` themselves are *not* part
    /// of the key: they change how a build runs, not what it produces.
    pub cache_dir: String,
}

impl Default for PartitionConfig {
    /// The `tiny()` partition defaults: single partition, 2-hop
    /// expansion, sequential build, caching off.
    fn default() -> Self {
        PartitionConfig {
            strategy: PartitionStrategy::Hdrf,
            num_partitions: 1,
            hops: 2,
            hdrf_lambda: 1.0,
            build_threads: 0,
            cache_dir: String::new(),
        }
    }
}

/// α-β interconnect model for the simulated cluster: transferring M bytes
/// costs `latency_us * 1e-6 + M / (bandwidth_gbps * 1e9 / 8)` seconds per
/// hop. Defaults model the paper's 40 Gb Ethernet.
#[derive(Clone, Debug)]
pub struct NetworkConfig {
    pub latency_us: f64,
    pub bandwidth_gbps: f64,
    /// Trainers per machine (paper: 2 per node, 2 GPUs each). Trainers on
    /// the same machine communicate at `local_bandwidth_gbps`.
    pub trainers_per_node: usize,
    pub local_bandwidth_gbps: f64,
}

#[derive(Clone, Debug)]
pub struct RuntimeConfig {
    pub artifacts_dir: String,
    /// Artifact family to load, e.g. "fbmini" -> artifacts/fbmini/.
    pub model_key: String,
}

#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    pub name: String,
    pub dataset: DatasetConfig,
    pub model: ModelConfig,
    pub train: TrainConfig,
    pub eval: EvalConfig,
    pub partition: PartitionConfig,
    pub network: NetworkConfig,
    pub runtime: RuntimeConfig,
    pub faults: FaultsConfig,
}

impl ExperimentConfig {
    /// Built-in defaults: the `tiny` tier (fast enough for unit tests).
    pub fn tiny() -> Self {
        ExperimentConfig {
            name: "tiny".into(),
            dataset: DatasetConfig {
                name: "tiny".into(),
                kind: DatasetKind::ZipfKg,
                entities: 300,
                relations: 8,
                train_edges: 2000,
                valid_edges: 150,
                test_edges: 150,
                feature_dim: 0,
                zipf_exponent: 1.1,
                seed: 1234,
            },
            model: ModelConfig {
                embed_dim: 16,
                num_bases: 2,
                num_layers: 2,
                dropout: 0.0,
                inverse_relations: true,
                self_loop: true,
            },
            train: TrainConfig {
                lr: 0.01,
                adam_beta1: 0.9,
                adam_beta2: 0.999,
                adam_eps: 1e-8,
                epochs: 10,
                batch_edges: 0,
                negatives_per_positive: 1,
                num_trainers: 1,
                grad_sync: GradSync::Ring,
                grad_mode: GradMode::Dense,
                local_negatives: true,
                seed: 7,
                eval_every: 0,
                host_threads: 0,
                prefetch_depth: 2,
                checkpoint_every_epochs: 0,
                checkpoint_dir: String::new(),
                checkpoint_keep: 3,
            },
            eval: EvalConfig { host_threads: 0, prefetch_depth: 2 },
            partition: PartitionConfig::default(),
            network: NetworkConfig {
                latency_us: 30.0,
                bandwidth_gbps: 40.0,
                trainers_per_node: 2,
                local_bandwidth_gbps: 160.0,
            },
            runtime: RuntimeConfig { artifacts_dir: "artifacts".into(), model_key: "tiny".into() },
            faults: FaultsConfig::default(),
        }
    }

    /// Load from a TOML file; missing keys keep the `tiny()` defaults.
    pub fn from_file(path: &str) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config file {path}"))?;
        Self::from_toml_str(&text).with_context(|| format!("parsing config file {path}"))
    }

    pub fn from_toml_str(text: &str) -> Result<Self> {
        let doc = toml::parse(text)?;
        let mut cfg = Self::tiny();
        if let Some(v) = doc.get_str("name") {
            cfg.name = v.to_string();
            cfg.dataset.name = v.to_string();
            cfg.runtime.model_key = v.to_string();
        }
        // dataset
        if let Some(v) = doc.get_str("dataset.kind") {
            cfg.dataset.kind = DatasetKind::from_str(v)?;
        }
        set_usize(&doc, "dataset.entities", &mut cfg.dataset.entities);
        set_usize(&doc, "dataset.relations", &mut cfg.dataset.relations);
        set_usize(&doc, "dataset.train_edges", &mut cfg.dataset.train_edges);
        set_usize(&doc, "dataset.valid_edges", &mut cfg.dataset.valid_edges);
        set_usize(&doc, "dataset.test_edges", &mut cfg.dataset.test_edges);
        set_usize(&doc, "dataset.feature_dim", &mut cfg.dataset.feature_dim);
        set_f64(&doc, "dataset.zipf_exponent", &mut cfg.dataset.zipf_exponent);
        set_u64(&doc, "dataset.seed", &mut cfg.dataset.seed);
        // model
        set_usize(&doc, "model.embed_dim", &mut cfg.model.embed_dim);
        set_usize(&doc, "model.num_bases", &mut cfg.model.num_bases);
        set_usize(&doc, "model.num_layers", &mut cfg.model.num_layers);
        set_f64(&doc, "model.dropout", &mut cfg.model.dropout);
        set_bool(&doc, "model.inverse_relations", &mut cfg.model.inverse_relations);
        set_bool(&doc, "model.self_loop", &mut cfg.model.self_loop);
        // train
        set_f64(&doc, "train.lr", &mut cfg.train.lr);
        set_usize(&doc, "train.epochs", &mut cfg.train.epochs);
        set_usize(&doc, "train.batch_edges", &mut cfg.train.batch_edges);
        set_usize(&doc, "train.negatives_per_positive", &mut cfg.train.negatives_per_positive);
        set_usize(&doc, "train.num_trainers", &mut cfg.train.num_trainers);
        set_bool(&doc, "train.local_negatives", &mut cfg.train.local_negatives);
        set_u64(&doc, "train.seed", &mut cfg.train.seed);
        set_usize(&doc, "train.eval_every", &mut cfg.train.eval_every);
        set_usize(&doc, "train.host_threads", &mut cfg.train.host_threads);
        set_usize(&doc, "train.prefetch_depth", &mut cfg.train.prefetch_depth);
        set_usize(
            &doc,
            "train.checkpoint_every_epochs",
            &mut cfg.train.checkpoint_every_epochs,
        );
        if let Some(v) = doc.get_str("train.checkpoint_dir") {
            cfg.train.checkpoint_dir = v.to_string();
        }
        set_usize(&doc, "train.checkpoint_keep", &mut cfg.train.checkpoint_keep);
        if let Some(v) = doc.get_str("train.grad_sync") {
            cfg.train.grad_sync = GradSync::from_str(v)?;
        }
        if let Some(v) = doc.get_str("train.grad_mode") {
            cfg.train.grad_mode = GradMode::from_str(v)?;
        }
        // eval
        set_usize(&doc, "eval.host_threads", &mut cfg.eval.host_threads);
        set_usize(&doc, "eval.prefetch_depth", &mut cfg.eval.prefetch_depth);
        // partition
        if let Some(v) = doc.get_str("partition.strategy") {
            cfg.partition.strategy = PartitionStrategy::from_str(v)?;
        }
        set_usize(&doc, "partition.num_partitions", &mut cfg.partition.num_partitions);
        set_usize(&doc, "partition.hops", &mut cfg.partition.hops);
        set_f64(&doc, "partition.hdrf_lambda", &mut cfg.partition.hdrf_lambda);
        set_usize(&doc, "partition.build_threads", &mut cfg.partition.build_threads);
        if let Some(v) = doc.get_str("partition.cache_dir") {
            cfg.partition.cache_dir = v.to_string();
        }
        // network
        set_f64(&doc, "network.latency_us", &mut cfg.network.latency_us);
        set_f64(&doc, "network.bandwidth_gbps", &mut cfg.network.bandwidth_gbps);
        set_usize(&doc, "network.trainers_per_node", &mut cfg.network.trainers_per_node);
        set_f64(&doc, "network.local_bandwidth_gbps", &mut cfg.network.local_bandwidth_gbps);
        // faults
        set_bool(&doc, "faults.enabled", &mut cfg.faults.enabled);
        set_u64(&doc, "faults.seed", &mut cfg.faults.seed);
        set_f64(&doc, "faults.crash_rate", &mut cfg.faults.crash_rate);
        set_f64(&doc, "faults.straggler_rate", &mut cfg.faults.straggler_rate);
        set_f64(&doc, "faults.slowdown_factor", &mut cfg.faults.slowdown_factor);
        set_usize(&doc, "faults.straggler_steps", &mut cfg.faults.straggler_steps);
        set_f64(&doc, "faults.link_degrade_rate", &mut cfg.faults.link_degrade_rate);
        set_f64(&doc, "faults.link_degrade_factor", &mut cfg.faults.link_degrade_factor);
        set_usize(&doc, "faults.link_degrade_steps", &mut cfg.faults.link_degrade_steps);
        set_f64(&doc, "faults.detect_secs", &mut cfg.faults.detect_secs);
        // runtime
        if let Some(v) = doc.get_str("runtime.artifacts_dir") {
            cfg.runtime.artifacts_dir = v.to_string();
        }
        if let Some(v) = doc.get_str("runtime.model_key") {
            cfg.runtime.model_key = v.to_string();
        }
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn validate(&self) -> Result<()> {
        if self.dataset.entities == 0 || self.dataset.relations == 0 {
            bail!("dataset must have entities > 0 and relations > 0");
        }
        if self.model.num_bases == 0 || self.model.embed_dim == 0 {
            bail!("model.embed_dim and model.num_bases must be > 0");
        }
        if self.model.num_layers == 0 {
            bail!("model.num_layers must be >= 1");
        }
        if self.partition.hops != self.model.num_layers {
            bail!(
                "partition.hops ({}) must equal model.num_layers ({}) for \
                 self-sufficient partitions (paper §3.2.2)",
                self.partition.hops,
                self.model.num_layers
            );
        }
        if self.train.num_trainers == 0 {
            bail!("train.num_trainers must be >= 1");
        }
        if !(0.0..1.0).contains(&self.model.dropout) {
            bail!("model.dropout must be in [0, 1)");
        }
        if self.train.negatives_per_positive == 0 {
            bail!("train.negatives_per_positive must be >= 1");
        }
        if self.train.grad_sync == GradSync::Sparse && self.train.grad_mode == GradMode::Dense {
            bail!(
                "train.grad_sync = \"sparse\" needs a sparse gradient path; set \
                 train.grad_mode = \"sparse\" or \"sparse_lazy\" (dense accumulation \
                 does not track touched rows)"
            );
        }
        if self.train.prefetch_depth == 0 {
            bail!("train.prefetch_depth must be >= 1 (1 = double buffering)");
        }
        if self.train.host_threads > 256 {
            bail!(
                "train.host_threads = {} is not a plausible host thread count \
                 (use 0 for the sequential path)",
                self.train.host_threads
            );
        }
        if self.eval.prefetch_depth == 0 {
            bail!("eval.prefetch_depth must be >= 1 (2 = double buffering)");
        }
        if self.eval.host_threads > 256 {
            bail!(
                "eval.host_threads = {} is not a plausible host thread count \
                 (use 0 for the sequential path)",
                self.eval.host_threads
            );
        }
        if self.partition.build_threads > 256 {
            bail!(
                "partition.build_threads = {} is not a plausible host thread count \
                 (use 0 for the sequential path)",
                self.partition.build_threads
            );
        }
        if self.train.checkpoint_every_epochs > 0 && self.train.checkpoint_dir.is_empty() {
            bail!(
                "train.checkpoint_every_epochs = {} needs a train.checkpoint_dir",
                self.train.checkpoint_every_epochs
            );
        }
        if self.train.checkpoint_keep == 0 {
            bail!("train.checkpoint_keep must be >= 1 (retention of the newest snapshot)");
        }
        for (key, rate) in [
            ("faults.crash_rate", self.faults.crash_rate),
            ("faults.straggler_rate", self.faults.straggler_rate),
            ("faults.link_degrade_rate", self.faults.link_degrade_rate),
        ] {
            if !(0.0..=1.0).contains(&rate) {
                bail!("{key} = {rate} must be a probability in [0, 1]");
            }
        }
        for (key, factor) in [
            ("faults.slowdown_factor", self.faults.slowdown_factor),
            ("faults.link_degrade_factor", self.faults.link_degrade_factor),
        ] {
            if factor < 1.0 || factor.is_nan() {
                bail!("{key} = {factor} must be >= 1 (a slowdown, not a speedup)");
            }
        }
        if self.faults.detect_secs < 0.0 || self.faults.detect_secs.is_nan() {
            bail!("faults.detect_secs = {} must be >= 0", self.faults.detect_secs);
        }
        if self.faults.enabled
            && self.faults.crash_rate > 0.0
            && self.train.checkpoint_every_epochs == 0
        {
            bail!(
                "faults.crash_rate > 0 needs checkpointing to recover from: set \
                 train.checkpoint_every_epochs > 0 (and train.checkpoint_dir)"
            );
        }
        Ok(())
    }

    /// Compact JSON summary — embedded in experiment result files so each
    /// result records the exact configuration that produced it.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::Str(self.name.clone())),
            (
                "dataset",
                Json::obj(vec![
                    ("entities", Json::Num(self.dataset.entities as f64)),
                    ("relations", Json::Num(self.dataset.relations as f64)),
                    ("train_edges", Json::Num(self.dataset.train_edges as f64)),
                    ("feature_dim", Json::Num(self.dataset.feature_dim as f64)),
                    ("seed", Json::Num(self.dataset.seed as f64)),
                ]),
            ),
            (
                "model",
                Json::obj(vec![
                    ("embed_dim", Json::Num(self.model.embed_dim as f64)),
                    ("num_bases", Json::Num(self.model.num_bases as f64)),
                    ("num_layers", Json::Num(self.model.num_layers as f64)),
                ]),
            ),
            (
                "train",
                Json::obj(vec![
                    ("lr", Json::Num(self.train.lr)),
                    ("epochs", Json::Num(self.train.epochs as f64)),
                    ("batch_edges", Json::Num(self.train.batch_edges as f64)),
                    ("num_trainers", Json::Num(self.train.num_trainers as f64)),
                    ("grad_mode", Json::Str(self.train.grad_mode.name().to_string())),
                ]),
            ),
        ])
    }
}

fn set_usize(doc: &toml::TomlDoc, key: &str, slot: &mut usize) {
    if let Some(v) = doc.get_usize(key) {
        *slot = v;
    }
}

fn set_u64(doc: &toml::TomlDoc, key: &str, slot: &mut u64) {
    if let Some(v) = doc.get(key).and_then(|v| v.as_i64()) {
        *slot = v as u64;
    }
}

fn set_f64(doc: &toml::TomlDoc, key: &str, slot: &mut f64) {
    if let Some(v) = doc.get_f64(key) {
        *slot = v;
    }
}

fn set_bool(doc: &toml::TomlDoc, key: &str, slot: &mut bool) {
    if let Some(v) = doc.get_bool(key) {
        *slot = v;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiny_defaults_validate() {
        ExperimentConfig::tiny().validate().unwrap();
    }

    #[test]
    fn toml_overrides_apply() {
        let cfg = ExperimentConfig::from_toml_str(
            r#"
name = "custom"
[dataset]
entities = 5000
relations = 12
[model]
embed_dim = 32
[train]
num_trainers = 4
grad_sync = "param_server"
[partition]
strategy = "dbh"
num_partitions = 4
"#,
        )
        .unwrap();
        assert_eq!(cfg.name, "custom");
        assert_eq!(cfg.dataset.entities, 5000);
        assert_eq!(cfg.model.embed_dim, 32);
        assert_eq!(cfg.train.grad_sync, GradSync::ParamServer);
        assert_eq!(cfg.partition.strategy, PartitionStrategy::Dbh);
        assert_eq!(cfg.partition.num_partitions, 4);
    }

    #[test]
    fn grad_mode_parses_and_sparse_sync_is_gated() {
        let cfg = ExperimentConfig::from_toml_str(
            "[train]\ngrad_mode = \"sparse_lazy\"\ngrad_sync = \"sparse\"\n",
        )
        .unwrap();
        assert_eq!(cfg.train.grad_mode, GradMode::SparseLazy);
        assert_eq!(cfg.train.grad_sync, GradSync::Sparse);
        // Default preserves the original dense semantics.
        assert_eq!(ExperimentConfig::tiny().train.grad_mode, GradMode::Dense);
        // Sparse sync without a sparse gradient path is rejected.
        let err = ExperimentConfig::from_toml_str("[train]\ngrad_sync = \"sparse\"\n")
            .unwrap_err()
            .to_string();
        assert!(err.contains("grad_mode"), "got: {err}");
        assert!(ExperimentConfig::from_toml_str("[train]\ngrad_mode = \"nope\"\n").is_err());
    }

    #[test]
    fn host_pipeline_keys_parse_and_validate() {
        let toml = "[train]\nhost_threads = 4\nprefetch_depth = 3\n";
        let cfg = ExperimentConfig::from_toml_str(toml).unwrap();
        assert_eq!(cfg.train.host_threads, 4);
        assert_eq!(cfg.train.prefetch_depth, 3);
        // Defaults: sequential reference path, double buffering.
        assert_eq!(ExperimentConfig::tiny().train.host_threads, 0);
        assert_eq!(ExperimentConfig::tiny().train.prefetch_depth, 2);
        let err = ExperimentConfig::from_toml_str("[train]\nprefetch_depth = 0\n")
            .unwrap_err()
            .to_string();
        assert!(err.contains("prefetch_depth"), "got: {err}");
        let err = ExperimentConfig::from_toml_str("[train]\nhost_threads = 100000\n")
            .unwrap_err()
            .to_string();
        assert!(err.contains("host_threads"), "got: {err}");
    }

    #[test]
    fn eval_pipeline_keys_parse_and_validate() {
        let toml = "[eval]\nhost_threads = 4\nprefetch_depth = 3\n";
        let cfg = ExperimentConfig::from_toml_str(toml).unwrap();
        assert_eq!(cfg.eval.host_threads, 4);
        assert_eq!(cfg.eval.prefetch_depth, 3);
        // Defaults: sequential reference path, double buffering.
        assert_eq!(ExperimentConfig::tiny().eval.host_threads, 0);
        assert_eq!(ExperimentConfig::tiny().eval.prefetch_depth, 2);
        let err = ExperimentConfig::from_toml_str("[eval]\nprefetch_depth = 0\n")
            .unwrap_err()
            .to_string();
        assert!(err.contains("eval.prefetch_depth"), "got: {err}");
        let err = ExperimentConfig::from_toml_str("[eval]\nhost_threads = 100000\n")
            .unwrap_err()
            .to_string();
        assert!(err.contains("eval.host_threads"), "got: {err}");
    }

    #[test]
    fn partition_build_keys_parse_and_validate() {
        let toml = "[partition]\nbuild_threads = 4\ncache_dir = \"artifacts/pcache\"\n";
        let cfg = ExperimentConfig::from_toml_str(toml).unwrap();
        assert_eq!(cfg.partition.build_threads, 4);
        assert_eq!(cfg.partition.cache_dir, "artifacts/pcache");
        // Defaults: sequential reference build, caching off.
        assert_eq!(ExperimentConfig::tiny().partition.build_threads, 0);
        assert_eq!(ExperimentConfig::tiny().partition.cache_dir, "");
        let err = ExperimentConfig::from_toml_str("[partition]\nbuild_threads = 100000\n")
            .unwrap_err()
            .to_string();
        assert!(err.contains("build_threads"), "got: {err}");
    }

    #[test]
    fn checkpoint_keys_parse_and_validate() {
        let toml = "[train]\ncheckpoint_every_epochs = 2\n\
                    checkpoint_dir = \"artifacts/ckpt\"\ncheckpoint_keep = 5\n";
        let cfg = ExperimentConfig::from_toml_str(toml).unwrap();
        assert_eq!(cfg.train.checkpoint_every_epochs, 2);
        assert_eq!(cfg.train.checkpoint_dir, "artifacts/ckpt");
        assert_eq!(cfg.train.checkpoint_keep, 5);
        // Defaults: checkpointing off, keep 3.
        assert_eq!(ExperimentConfig::tiny().train.checkpoint_every_epochs, 0);
        assert_eq!(ExperimentConfig::tiny().train.checkpoint_keep, 3);
        // Cadence without a directory is rejected.
        let err = ExperimentConfig::from_toml_str("[train]\ncheckpoint_every_epochs = 2\n")
            .unwrap_err()
            .to_string();
        assert!(err.contains("checkpoint_dir"), "got: {err}");
        let err = ExperimentConfig::from_toml_str("[train]\ncheckpoint_keep = 0\n")
            .unwrap_err()
            .to_string();
        assert!(err.contains("checkpoint_keep"), "got: {err}");
    }

    #[test]
    fn faults_keys_parse_and_validate() {
        let toml = "[train]\ncheckpoint_every_epochs = 1\ncheckpoint_dir = \"d\"\n\
                    [faults]\nenabled = true\nseed = 99\ncrash_rate = 0.1\n\
                    straggler_rate = 0.25\nslowdown_factor = 3.0\nstraggler_steps = 4\n\
                    link_degrade_rate = 0.5\nlink_degrade_factor = 2.0\n\
                    link_degrade_steps = 6\ndetect_secs = 0.5\n";
        let cfg = ExperimentConfig::from_toml_str(toml).unwrap();
        assert!(cfg.faults.enabled);
        assert_eq!(cfg.faults.seed, 99);
        assert_eq!(cfg.faults.crash_rate, 0.1);
        assert_eq!(cfg.faults.straggler_rate, 0.25);
        assert_eq!(cfg.faults.slowdown_factor, 3.0);
        assert_eq!(cfg.faults.straggler_steps, 4);
        assert_eq!(cfg.faults.link_degrade_rate, 0.5);
        assert_eq!(cfg.faults.link_degrade_factor, 2.0);
        assert_eq!(cfg.faults.link_degrade_steps, 6);
        assert_eq!(cfg.faults.detect_secs, 0.5);
        // Defaults: disabled, rates zero.
        let tiny = ExperimentConfig::tiny();
        assert!(!tiny.faults.enabled);
        assert_eq!(tiny.faults.crash_rate, 0.0);
        // Out-of-range rate rejected.
        let err = ExperimentConfig::from_toml_str("[faults]\ncrash_rate = 1.5\n")
            .unwrap_err()
            .to_string();
        assert!(err.contains("crash_rate"), "got: {err}");
        // Sub-unity slowdown rejected.
        let err = ExperimentConfig::from_toml_str("[faults]\nslowdown_factor = 0.5\n")
            .unwrap_err()
            .to_string();
        assert!(err.contains("slowdown_factor"), "got: {err}");
        // Crashes without checkpointing to recover from are rejected.
        let err = ExperimentConfig::from_toml_str(
            "[faults]\nenabled = true\ncrash_rate = 0.1\n",
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("checkpoint_every_epochs"), "got: {err}");
    }

    #[test]
    fn grad_mode_tag_roundtrip() {
        for m in [GradMode::Dense, GradMode::Sparse, GradMode::SparseLazy] {
            assert_eq!(GradMode::from_u32(m.as_u32()).unwrap(), m);
            assert_eq!(GradMode::from_str(m.name()).unwrap(), m);
        }
        assert!(GradMode::from_u32(9).is_err());
    }

    #[test]
    fn hops_layers_mismatch_rejected() {
        let err = ExperimentConfig::from_toml_str(
            "[partition]\nhops = 3\n",
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("self-sufficient"), "got: {err}");
    }

    #[test]
    fn bad_enum_value_rejected() {
        assert!(ExperimentConfig::from_toml_str("[partition]\nstrategy = \"kahip\"\n").is_err());
        assert!(ExperimentConfig::from_toml_str("[dataset]\nkind = \"nope\"\n").is_err());
    }

    #[test]
    fn config_json_summary_contains_key_fields() {
        let j = ExperimentConfig::tiny().to_json().to_string();
        assert!(j.contains("\"entities\""));
        assert!(j.contains("\"embed_dim\""));
    }
}

//! TOML-subset parser for experiment configuration files.
//!
//! Supports the subset actually used by `configs/*.toml`:
//! `[section]` and `[section.sub]` headers, `key = value` with string,
//! integer, float, boolean, and homogeneous-array values, `#` comments.
//! No multi-line strings, no dates, no array-of-tables — config files in
//! this repo do not need them, and failing loudly on unsupported syntax
//! is safer than a partial parse.

use std::collections::BTreeMap;

/// A parsed TOML value.
#[derive(Clone, Debug, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Arr(Vec<TomlValue>),
}

impl TomlValue {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            TomlValue::Int(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_i64().and_then(|x| usize::try_from(x).ok())
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            TomlValue::Float(x) => Some(*x),
            TomlValue::Int(x) => Some(*x as f64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// Flat document: dotted section path + key -> value
/// (`[train]` + `lr = 0.01` becomes `"train.lr"`).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct TomlDoc {
    pub entries: BTreeMap<String, TomlValue>,
}

impl TomlDoc {
    pub fn get(&self, dotted: &str) -> Option<&TomlValue> {
        self.entries.get(dotted)
    }

    pub fn get_str(&self, dotted: &str) -> Option<&str> {
        self.get(dotted).and_then(|v| v.as_str())
    }

    pub fn get_usize(&self, dotted: &str) -> Option<usize> {
        self.get(dotted).and_then(|v| v.as_usize())
    }

    pub fn get_f64(&self, dotted: &str) -> Option<f64> {
        self.get(dotted).and_then(|v| v.as_f64())
    }

    pub fn get_bool(&self, dotted: &str) -> Option<bool> {
        self.get(dotted).and_then(|v| v.as_bool())
    }

    /// Keys under a section prefix, e.g. `section_keys("train")`.
    pub fn section_keys(&self, prefix: &str) -> Vec<&str> {
        let want = format!("{prefix}.");
        self.entries.keys().filter(|k| k.starts_with(&want)).map(|k| k.as_str()).collect()
    }
}

/// Parse a TOML-subset document.
pub fn parse(input: &str) -> anyhow::Result<TomlDoc> {
    let mut doc = TomlDoc::default();
    let mut section = String::new();
    for (lineno, raw) in input.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('[') {
            let name = rest
                .strip_suffix(']')
                .ok_or_else(|| anyhow::anyhow!("line {}: unterminated section header", lineno + 1))?
                .trim();
            if name.is_empty() {
                anyhow::bail!("line {}: empty section name", lineno + 1);
            }
            section = name.to_string();
            continue;
        }
        let eq = line
            .find('=')
            .ok_or_else(|| anyhow::anyhow!("line {}: expected `key = value`", lineno + 1))?;
        let key = line[..eq].trim();
        let val_src = line[eq + 1..].trim();
        if key.is_empty() || val_src.is_empty() {
            anyhow::bail!("line {}: empty key or value", lineno + 1);
        }
        let value = parse_value(val_src)
            .map_err(|e| anyhow::anyhow!("line {}: {e}", lineno + 1))?;
        let full_key =
            if section.is_empty() { key.to_string() } else { format!("{section}.{key}") };
        if doc.entries.insert(full_key.clone(), value).is_some() {
            anyhow::bail!("line {}: duplicate key {full_key:?}", lineno + 1);
        }
    }
    Ok(doc)
}

fn strip_comment(line: &str) -> &str {
    // A '#' inside a quoted string must not start a comment.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(src: &str) -> anyhow::Result<TomlValue> {
    if let Some(rest) = src.strip_prefix('"') {
        let end = rest
            .find('"')
            .ok_or_else(|| anyhow::anyhow!("unterminated string"))?;
        if !rest[end + 1..].trim().is_empty() {
            anyhow::bail!("trailing characters after string");
        }
        return Ok(TomlValue::Str(rest[..end].to_string()));
    }
    if src == "true" {
        return Ok(TomlValue::Bool(true));
    }
    if src == "false" {
        return Ok(TomlValue::Bool(false));
    }
    if let Some(inner) = src.strip_prefix('[') {
        let inner = inner
            .strip_suffix(']')
            .ok_or_else(|| anyhow::anyhow!("unterminated array"))?;
        let mut items = Vec::new();
        let trimmed = inner.trim();
        if !trimmed.is_empty() {
            for part in split_top_level(trimmed) {
                items.push(parse_value(part.trim())?);
            }
        }
        return Ok(TomlValue::Arr(items));
    }
    // Numbers: underscores allowed as separators, like real TOML.
    let cleaned: String = src.chars().filter(|&c| c != '_').collect();
    if cleaned.contains('.') || cleaned.contains('e') || cleaned.contains('E') {
        if let Ok(x) = cleaned.parse::<f64>() {
            return Ok(TomlValue::Float(x));
        }
    }
    if let Ok(x) = cleaned.parse::<i64>() {
        return Ok(TomlValue::Int(x));
    }
    anyhow::bail!("cannot parse value {src:?}")
}

/// Split an array body on commas, respecting quoted strings.
fn split_top_level(s: &str) -> Vec<&str> {
    let mut parts = Vec::new();
    let mut in_str = false;
    let mut start = 0;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            ',' if !in_str => {
                parts.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    parts.push(&s[start..]);
    parts
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_document() {
        let doc = parse(
            r#"
# experiment config
name = "fb-mini"           # inline comment
[dataset]
entities = 2_500
relations = 40
zipf = 1.15
[train]
lr = 0.01
full_batch = true
trainers = [1, 2, 4, 8]
labels = ["a", "b"]
"#,
        )
        .unwrap();
        assert_eq!(doc.get_str("name"), Some("fb-mini"));
        assert_eq!(doc.get_usize("dataset.entities"), Some(2500));
        assert_eq!(doc.get_f64("dataset.zipf"), Some(1.15));
        assert_eq!(doc.get_f64("train.lr"), Some(0.01));
        assert_eq!(doc.get_bool("train.full_batch"), Some(true));
        let arr = doc.get("train.trainers").unwrap();
        assert_eq!(
            arr,
            &TomlValue::Arr(vec![TomlValue::Int(1), TomlValue::Int(2), TomlValue::Int(4), TomlValue::Int(8)])
        );
        assert_eq!(doc.section_keys("train").len(), 4);
    }

    #[test]
    fn hash_inside_string_is_not_comment() {
        let doc = parse("key = \"a#b\"").unwrap();
        assert_eq!(doc.get_str("key"), Some("a#b"));
    }

    #[test]
    fn errors_are_precise() {
        assert!(parse("[unterminated").is_err());
        assert!(parse("novalue =").is_err());
        assert!(parse("x = @@").is_err());
        let err = parse("a = 1\na = 2").unwrap_err().to_string();
        assert!(err.contains("duplicate"));
    }

    #[test]
    fn int_vs_float_distinction() {
        let doc = parse("a = 3\nb = 3.0\nc = 1e-3").unwrap();
        assert_eq!(doc.get("a"), Some(&TomlValue::Int(3)));
        assert_eq!(doc.get("b"), Some(&TomlValue::Float(3.0)));
        assert_eq!(doc.get_f64("c"), Some(1e-3));
        // ints coerce to f64 on demand
        assert_eq!(doc.get_f64("a"), Some(3.0));
    }
}

//! Micro-benchmark harness for the `cargo bench` targets (criterion is
//! unavailable offline). Warms up, then runs timed iterations and prints
//! a stable one-line summary; returns the stats for table assembly.

use super::stats::{humanize_secs, Welford};
use super::timer::Stopwatch;

#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub mean_secs: f64,
    pub std_secs: f64,
    pub min_secs: f64,
    pub iters: u64,
}

impl BenchResult {
    pub fn summary(&self) -> String {
        format!(
            "{:<44} {:>12} ± {:>10}  (min {:>10}, n={})",
            self.name,
            humanize_secs(self.mean_secs),
            humanize_secs(self.std_secs),
            humanize_secs(self.min_secs),
            self.iters
        )
    }
}

/// Benchmark `f`, auto-scaling iterations to roughly `budget_secs`.
pub fn bench(name: &str, budget_secs: f64, mut f: impl FnMut()) -> BenchResult {
    // Warmup + calibration.
    let sw = Stopwatch::new();
    f();
    let once = sw.elapsed_secs().max(1e-9);
    let iters = ((budget_secs / once) as u64).clamp(3, 10_000);
    let mut w = Welford::new();
    for _ in 0..iters {
        let sw = Stopwatch::new();
        f();
        w.push(sw.elapsed_secs());
    }
    let r = BenchResult {
        name: name.to_string(),
        mean_secs: w.mean(),
        std_secs: w.std(),
        min_secs: w.min(),
        iters,
    };
    println!("{}", r.summary());
    r
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let r = bench("noop-spin", 0.02, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(r.iters >= 3);
        assert!(r.mean_secs >= 0.0);
        assert!(r.min_secs <= r.mean_secs + 1e-12);
        assert!(r.summary().contains("noop-spin"));
    }
}

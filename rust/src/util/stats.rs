//! Small descriptive-statistics helpers used by benchmarks, the metrics
//! registry, and the partition-quality reports (Table 2 / Table 5 report
//! "mean ± std" of partition sizes).

/// Running mean/variance via Welford's algorithm — numerically stable,
/// single pass, O(1) memory. Used in hot loops (per-batch timings).
#[derive(Clone, Debug, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Welford {
    pub fn new() -> Self {
        Self { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let delta = x - self.mean;
        self.mean += delta / self.n as f64;
        self.m2 += delta * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Population variance (n, not n-1): we report over complete runs.
    pub fn variance(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn sum(&self) -> f64 {
        self.mean * self.n as f64
    }

    pub fn min(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.min }
    }

    pub fn max(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.max }
    }
}

/// Mean of a slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation of a slice.
pub fn std(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Percentile with linear interpolation; `q` in [0, 100].
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut sorted = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = q / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = rank - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

/// Median.
pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Format a count with k/M suffixes the way the paper's tables do
/// ("136k ± 4.5k", "15M ± 485K").
pub fn humanize_count(x: f64) -> String {
    let ax = x.abs();
    if ax >= 1e6 {
        format!("{:.2}M", x / 1e6)
    } else if ax >= 1e3 {
        format!("{:.1}k", x / 1e3)
    } else {
        format!("{x:.0}")
    }
}

/// Format a duration in seconds adaptively (µs/ms/s/min).
pub fn humanize_secs(s: f64) -> String {
    if s < 1e-3 {
        format!("{:.1}µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else if s < 120.0 {
        format!("{s:.2}s")
    } else {
        format!("{:.1}min", s / 60.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_naive() {
        let xs = [1.0, 2.0, 3.0, 4.0, 10.0];
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        assert!((w.mean() - mean(&xs)).abs() < 1e-12);
        assert!((w.std() - std(&xs)).abs() < 1e-12);
        assert_eq!(w.min(), 1.0);
        assert_eq!(w.max(), 10.0);
        assert_eq!(w.count(), 5);
        assert!((w.sum() - 20.0).abs() < 1e-9);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [10.0, 20.0, 30.0, 40.0];
        assert_eq!(percentile(&xs, 0.0), 10.0);
        assert_eq!(percentile(&xs, 100.0), 40.0);
        assert_eq!(median(&xs), 25.0);
        assert_eq!(percentile(&xs, 50.0), 25.0);
    }

    #[test]
    fn empty_inputs_do_not_panic() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(std(&[]), 0.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
        let w = Welford::new();
        assert_eq!(w.mean(), 0.0);
        assert_eq!(w.min(), 0.0);
    }

    #[test]
    fn humanize_formats() {
        assert_eq!(humanize_count(136_000.0), "136.0k");
        assert_eq!(humanize_count(15_000_000.0), "15.00M");
        assert_eq!(humanize_count(42.0), "42");
        assert!(humanize_secs(0.0000005).ends_with("µs"));
        assert!(humanize_secs(0.005).ends_with("ms"));
        assert!(humanize_secs(5.09).ends_with('s'));
        assert!(humanize_secs(7.0 * 60.0).ends_with("min"));
    }
}

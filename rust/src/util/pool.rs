//! Shared host thread pool.
//!
//! One pool implementation serves every host-side overlap path in the
//! system: the training data pipeline ([`train::pipeline`]) uses it to
//! prepare batches while the coordinator executes XLA, and the
//! evaluation pipeline ([`eval::pipeline`]) uses it to compute filtered
//! ranks for an already-scored chunk while the next chunk executes.
//! Jobs are plain-data closures — no xla types ever cross a thread
//! boundary; the PJRT runtime stays pinned to the coordinator.
//!
//! [`train::pipeline`]: crate::train::pipeline
//! [`eval::pipeline`]: crate::eval::pipeline

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// A persistent pool of host threads fed over an mpsc channel.
///
/// Jobs are claimed by whichever thread is free (one shared receiver
/// behind a mutex); result ordering is restored downstream by tagging
/// results with their origin (worker id, chunk index), never by relying
/// on completion order. Dropping the pool closes the channel and joins
/// every thread.
pub struct HostPool {
    tx: Option<mpsc::Sender<Job>>,
    handles: Vec<thread::JoinHandle<()>>,
}

impl HostPool {
    pub fn new(threads: usize) -> HostPool {
        assert!(threads > 0, "HostPool needs at least one thread");
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let handles = (0..threads)
            .map(|i| {
                let rx = Arc::clone(&rx);
                thread::Builder::new()
                    .name(format!("kgscale-host-{i}"))
                    .spawn(move || loop {
                        // The lock guards only the `recv`; the temporary
                        // guard is released at the `;`, so other threads
                        // claim work while this job runs.
                        let job = rx.lock().expect("host pool receiver poisoned").recv();
                        match job {
                            Ok(job) => job(),
                            Err(_) => break, // channel closed: pool dropped
                        }
                    })
                    .expect("spawn host pool thread")
            })
            .collect();
        HostPool { tx: Some(tx), handles }
    }

    pub fn threads(&self) -> usize {
        self.handles.len()
    }

    /// Queue a job; any idle pool thread picks it up.
    pub fn submit(&self, job: impl FnOnce() + Send + 'static) {
        self.tx
            .as_ref()
            .expect("sender lives until drop")
            .send(Box::new(job))
            .expect("host pool threads alive");
    }
}

impl Drop for HostPool {
    fn drop(&mut self) {
        // Closing the channel lets workers drain queued jobs and exit.
        drop(self.tx.take());
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn host_pool_runs_every_job_and_joins_on_drop() {
        let pool = HostPool::new(3);
        assert_eq!(pool.threads(), 3);
        let counter = Arc::new(AtomicUsize::new(0));
        let (tx, rx) = mpsc::channel();
        for i in 0..64usize {
            let counter = Arc::clone(&counter);
            let tx = tx.clone();
            pool.submit(move || {
                counter.fetch_add(1, Ordering::SeqCst);
                tx.send(i).unwrap();
            });
        }
        drop(tx);
        let mut got: Vec<usize> = rx.iter().collect();
        got.sort_unstable();
        assert_eq!(got, (0..64).collect::<Vec<_>>());
        assert_eq!(counter.load(Ordering::SeqCst), 64);
        drop(pool); // joins cleanly once the queue has drained
    }
}

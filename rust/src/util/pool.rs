//! Shared host thread pool.
//!
//! One pool implementation serves every host-side overlap path in the
//! system: the training data pipeline ([`train::pipeline`]) uses it to
//! prepare batches while the coordinator executes XLA, and the
//! evaluation pipeline ([`eval::pipeline`]) uses it to compute filtered
//! ranks for an already-scored chunk while the next chunk executes.
//! Jobs are plain-data closures — no xla types ever cross a thread
//! boundary; the PJRT runtime stays pinned to the coordinator.
//!
//! [`train::pipeline`]: crate::train::pipeline
//! [`eval::pipeline`]: crate::eval::pipeline

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// One-shot scoped fan-out over indices `0..n`, the build-stage
/// counterpart of [`HostPool`].
///
/// [`HostPool`] jobs must be `'static`, which suits the steady-state
/// train/eval loops (plain-data closures, `Arc`-shared inputs) but not
/// one-shot preprocessing that borrows large read-only state from the
/// caller's stack (graph, CSR, edge assignment). `scoped_map` runs the
/// same claim-next-index discipline on transient `std::thread::scope`
/// workers, which may borrow: every worker joins before this function
/// returns.
///
/// Each worker builds one `state` via `init` and reuses it across every
/// index it claims (work stealing over a shared atomic cursor) — the
/// hook for arena-style scratch that must not be reallocated per item.
/// Results are collected **in index order**, never completion order, so
/// the output is identical for any `threads` count.
pub fn scoped_map<T, S>(
    threads: usize,
    n: usize,
    init: impl Fn() -> S + Sync,
    work: impl Fn(&mut S, usize) -> T + Sync,
) -> Vec<T>
where
    T: Send,
{
    assert!(threads > 0, "scoped_map needs at least one worker thread");
    let next = AtomicUsize::new(0);
    let (tx, rx) = mpsc::channel::<(usize, T)>();
    let mut out: Vec<Option<T>> = Vec::with_capacity(n);
    out.resize_with(n, || None);
    thread::scope(|s| {
        for _ in 0..threads.min(n) {
            let tx = tx.clone();
            let next = &next;
            let init = &init;
            let work = &work;
            s.spawn(move || {
                let mut state = init();
                loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    let item = work(&mut state, i);
                    if tx.send((i, item)).is_err() {
                        break;
                    }
                }
            });
        }
        drop(tx);
        for (i, item) in rx {
            out[i] = Some(item);
        }
    });
    out.into_iter().map(|item| item.expect("scoped_map produced every index")).collect()
}

/// A persistent pool of host threads fed over an mpsc channel.
///
/// Jobs are claimed by whichever thread is free (one shared receiver
/// behind a mutex); result ordering is restored downstream by tagging
/// results with their origin (worker id, chunk index), never by relying
/// on completion order. Dropping the pool closes the channel and joins
/// every thread.
pub struct HostPool {
    tx: Option<mpsc::Sender<Job>>,
    handles: Vec<thread::JoinHandle<()>>,
}

impl HostPool {
    pub fn new(threads: usize) -> HostPool {
        assert!(threads > 0, "HostPool needs at least one thread");
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let handles = (0..threads)
            .map(|i| {
                let rx = Arc::clone(&rx);
                thread::Builder::new()
                    .name(format!("kgscale-host-{i}"))
                    .spawn(move || loop {
                        // The lock guards only the `recv`; the temporary
                        // guard is released at the `;`, so other threads
                        // claim work while this job runs.
                        let job = rx.lock().expect("host pool receiver poisoned").recv();
                        match job {
                            Ok(job) => job(),
                            Err(_) => break, // channel closed: pool dropped
                        }
                    })
                    .expect("spawn host pool thread")
            })
            .collect();
        HostPool { tx: Some(tx), handles }
    }

    pub fn threads(&self) -> usize {
        self.handles.len()
    }

    /// Queue a job; any idle pool thread picks it up.
    pub fn submit(&self, job: impl FnOnce() + Send + 'static) {
        self.tx
            .as_ref()
            .expect("sender lives until drop")
            .send(Box::new(job))
            .expect("host pool threads alive");
    }
}

impl Drop for HostPool {
    fn drop(&mut self) {
        // Closing the channel lets workers drain queued jobs and exit.
        drop(self.tx.take());
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn host_pool_runs_every_job_and_joins_on_drop() {
        let pool = HostPool::new(3);
        assert_eq!(pool.threads(), 3);
        let counter = Arc::new(AtomicUsize::new(0));
        let (tx, rx) = mpsc::channel();
        for i in 0..64usize {
            let counter = Arc::clone(&counter);
            let tx = tx.clone();
            pool.submit(move || {
                counter.fetch_add(1, Ordering::SeqCst);
                tx.send(i).unwrap();
            });
        }
        drop(tx);
        let mut got: Vec<usize> = rx.iter().collect();
        got.sort_unstable();
        assert_eq!(got, (0..64).collect::<Vec<_>>());
        assert_eq!(counter.load(Ordering::SeqCst), 64);
        drop(pool); // joins cleanly once the queue has drained
    }

    #[test]
    fn scoped_map_orders_results_and_reuses_state() {
        // Borrow caller-stack data (the whole point vs HostPool)...
        let inputs: Vec<usize> = (0..40).collect();
        // ...and count state constructions: one per worker, not per item.
        let states = AtomicUsize::new(0);
        for threads in [1usize, 3, 8, 64] {
            let got = scoped_map(
                threads,
                inputs.len(),
                || {
                    states.fetch_add(1, Ordering::SeqCst);
                    0usize // per-worker accumulator, reused across items
                },
                |acc, i| {
                    *acc += 1;
                    inputs[i] * 2
                },
            );
            let want: Vec<usize> = inputs.iter().map(|x| x * 2).collect();
            assert_eq!(got, want, "threads={threads}: results must be in index order");
        }
        assert!(
            states.load(Ordering::SeqCst) <= 1 + 3 + 8 + 40,
            "states are per-worker (capped at min(threads, n)), never per item"
        );
    }

    #[test]
    fn scoped_map_empty_range() {
        let got: Vec<u32> = scoped_map(4, 0, || (), |_, _| unreachable!());
        assert!(got.is_empty());
    }
}

//! Deterministic pseudo-random number generation.
//!
//! The crate cannot depend on `rand` offline, so we ship a small,
//! well-tested PRNG stack: [`SplitMix64`] for seeding and
//! [`Xoshiro256StarStar`] as the workhorse generator (the same pair used
//! by the reference `rand_xoshiro` implementation). Every stochastic
//! component in the system (graph generation, partition tie-breaking,
//! negative sampling, batch shuffling, parameter init) takes an explicit
//! seed so runs are exactly reproducible.

/// SplitMix64 — used to expand a single `u64` seed into generator state.
///
/// Reference: Sebastiano Vigna, <https://prng.di.unimi.it/splitmix64.c>.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// Xoshiro256** — fast, high-quality 64-bit PRNG.
///
/// Reference: Blackman & Vigna, <https://prng.di.unimi.it/xoshiro256starstar.c>.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Construct from a single seed via SplitMix64 expansion.
    pub fn seeded(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let mut s = [0u64; 4];
        for v in s.iter_mut() {
            *v = sm.next_u64();
        }
        // All-zero state is the one invalid state; SplitMix64 cannot
        // produce four consecutive zeros, but be defensive anyway.
        if s == [0, 0, 0, 0] {
            s[0] = 0x9E3779B97F4A7C15;
        }
        Self { s }
    }

    /// Derive an independent child generator (e.g. one per worker).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::seeded(self.next_u64() ^ stream.wrapping_mul(0xA24BAED4963EE407))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, bound)` using Lemire's multiply-shift rejection.
    #[inline]
    pub fn next_below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0, "next_below(0)");
        loop {
            let x = self.next_u64();
            let m = (x as u128) * (bound as u128);
            let low = m as u64;
            if low >= bound {
                return (m >> 64) as u64;
            }
            // Rejection zone: only retry when low < bound and would bias.
            let threshold = bound.wrapping_neg() % bound;
            if low >= threshold {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform usize in `[0, bound)`.
    #[inline]
    pub fn below(&mut self, bound: usize) -> usize {
        self.next_below(bound as u64) as usize
    }

    /// Uniform f64 in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform f32 in `[lo, hi)`.
    #[inline]
    pub fn uniform_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.next_f32()
    }

    /// Standard normal via Box-Muller (cached spare not kept — callers in
    /// hot paths should prefer uniform init anyway).
    pub fn next_gaussian(&mut self) -> f64 {
        loop {
            let u = 2.0 * self.next_f64() - 1.0;
            let v = 2.0 * self.next_f64() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                return u * (-2.0 * s.ln() / s).sqrt();
            }
        }
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (k must be <= n).
    /// Uses Floyd's algorithm: O(k) expected, no allocation beyond output.
    pub fn sample_distinct(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "sample_distinct: k={k} > n={n}");
        let mut chosen = std::collections::HashSet::with_capacity(k);
        let mut out = Vec::with_capacity(k);
        for j in (n - k)..n {
            let t = self.below(j + 1);
            let pick = if chosen.contains(&t) { j } else { t };
            chosen.insert(pick);
            out.push(pick);
        }
        out
    }
}

/// Zipf-distributed sampler over `[0, n)` with exponent `s`, built on the
/// rejection-inversion method of Hörmann & Derflinger — O(1) per sample,
/// used by the synthetic KG generator to produce the skewed degree
/// distributions the paper observes in enterprise graphs.
#[derive(Clone, Debug)]
pub struct Zipf {
    n: f64,
    s: f64,
    h_x1: f64,
    h_n: f64,
    dense: Option<Vec<f64>>, // small-n fallback: CDF table
}

impl Zipf {
    pub fn new(n: usize, s: f64) -> Self {
        assert!(n >= 1);
        if n < 64 || (s - 1.0).abs() < 1e-9 {
            // Small domains (or s==1 where the H integral needs the log
            // branch): build an explicit CDF — exact and cheap.
            let mut cdf = Vec::with_capacity(n);
            let mut acc = 0.0;
            for k in 1..=n {
                acc += (k as f64).powf(-s);
                cdf.push(acc);
            }
            let total = acc;
            for v in cdf.iter_mut() {
                *v /= total;
            }
            return Self { n: n as f64, s, h_x1: 0.0, h_n: 0.0, dense: Some(cdf) };
        }
        let h = |x: f64| -> f64 { (x.powf(1.0 - s) - 1.0) / (1.0 - s) };
        Self { n: n as f64, s, h_x1: h(1.5) - 1.0, h_n: h(n as f64 + 0.5), dense: None }
    }

    /// Sample a value in `[0, n)` (0-based; rank 0 is most frequent).
    pub fn sample(&self, rng: &mut Rng) -> usize {
        if let Some(cdf) = &self.dense {
            let u = rng.next_f64();
            let idx = match cdf.binary_search_by(|p| p.partial_cmp(&u).unwrap()) {
                Ok(i) => i,
                Err(i) => i,
            };
            return idx.min(cdf.len() - 1);
        }
        let s = self.s;
        let h_inv = |x: f64| -> f64 { (1.0 + x * (1.0 - s)).powf(1.0 / (1.0 - s)) };
        loop {
            let u = self.h_x1 + rng.next_f64() * (self.h_n - self.h_x1);
            let x = h_inv(u);
            let k = (x + 0.5).floor().max(1.0);
            let h = |y: f64| -> f64 { (y.powf(1.0 - s) - 1.0) / (1.0 - s) };
            if u >= h(k + 0.5) - k.powf(-s) {
                return (k as usize - 1).min(self.n as usize - 1);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_values() {
        // First outputs for seed=0 from the reference C implementation.
        let mut sm = SplitMix64::new(0);
        assert_eq!(sm.next_u64(), 0xE220A8397B1DCDAF);
        assert_eq!(sm.next_u64(), 0x6E789E6AA1B965F4);
    }

    #[test]
    fn deterministic_and_seed_sensitive() {
        let mut a = Rng::seeded(42);
        let mut b = Rng::seeded(42);
        let mut c = Rng::seeded(43);
        let xs: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let ys: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        let zs: Vec<u64> = (0..8).map(|_| c.next_u64()).collect();
        assert_eq!(xs, ys);
        assert_ne!(xs, zs);
    }

    #[test]
    fn next_below_in_range_and_roughly_uniform() {
        let mut rng = Rng::seeded(7);
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            let v = rng.next_below(10) as usize;
            counts[v] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "bucket count {c} far from uniform");
        }
    }

    #[test]
    fn f64_unit_interval() {
        let mut rng = Rng::seeded(1);
        for _ in 0..10_000 {
            let x = rng.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Rng::seeded(3);
        let mut xs: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn sample_distinct_properties() {
        let mut rng = Rng::seeded(11);
        for _ in 0..50 {
            let n = 1 + rng.below(200);
            let k = rng.below(n + 1);
            let s = rng.sample_distinct(n, k);
            assert_eq!(s.len(), k);
            let set: std::collections::HashSet<_> = s.iter().collect();
            assert_eq!(set.len(), k, "duplicates in sample");
            assert!(s.iter().all(|&i| i < n));
        }
    }

    #[test]
    fn zipf_is_skewed_and_in_range() {
        let mut rng = Rng::seeded(5);
        let z = Zipf::new(1000, 1.2);
        let mut counts = vec![0usize; 1000];
        for _ in 0..50_000 {
            counts[z.sample(&mut rng)] += 1;
        }
        // Rank 0 should dominate rank 100 heavily under s=1.2.
        assert!(counts[0] > counts[99] * 5, "zipf not skewed: {} vs {}", counts[0], counts[99]);
    }

    #[test]
    fn zipf_small_n_dense_path() {
        let mut rng = Rng::seeded(9);
        let z = Zipf::new(3, 1.0);
        let mut counts = [0usize; 3];
        for _ in 0..30_000 {
            counts[z.sample(&mut rng)] += 1;
        }
        assert!(counts[0] > counts[1] && counts[1] > counts[2]);
    }

    #[test]
    fn fork_streams_decorrelated() {
        let mut root = Rng::seeded(42);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        let xa: Vec<u64> = (0..4).map(|_| a.next_u64()).collect();
        let xb: Vec<u64> = (0..4).map(|_| b.next_u64()).collect();
        assert_ne!(xa, xb);
    }
}

//! Cross-cutting utilities: deterministic RNG, JSON, descriptive stats,
//! timing, logging. Everything here is dependency-free (std only) because
//! the build is fully offline — see DESIGN.md.

pub mod bench;
pub mod hash;
pub mod json;
pub mod logging;
pub mod pool;
pub mod rng;
pub mod stats;
pub mod timer;

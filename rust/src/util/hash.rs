//! Streaming FNV-1a (64-bit) hashing.
//!
//! Stable across platforms, runs, and compiler versions — unlike
//! `DefaultHasher`, whose algorithm is explicitly unspecified — so it is
//! safe to persist on disk. Used for the partition-cache content key
//! (`partition::cache`) and the checkpoint integrity footer
//! (`train::checkpoint`). Not cryptographic: it detects corruption
//! (bit flips, truncation, torn writes), not adversaries.

/// Streaming FNV-1a over 64 bits. `new()` starts at the standard offset
/// basis; feed bytes with [`write`](Fnv64::write) and read the digest
/// with [`finish`](Fnv64::finish).
pub struct Fnv64(u64);

impl Fnv64 {
    pub fn new() -> Fnv64 {
        Fnv64(0xcbf2_9ce4_8422_2325)
    }

    pub fn write(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(0x100_0000_01b3);
        }
    }

    pub fn write_u32(&mut self, v: u32) {
        self.write(&v.to_le_bytes());
    }

    pub fn write_u64(&mut self, v: u64) {
        self.write(&v.to_le_bytes());
    }

    pub fn finish(&self) -> u64 {
        self.0
    }
}

impl Default for Fnv64 {
    fn default() -> Self {
        Fnv64::new()
    }
}

/// One-shot FNV-1a 64 of a byte slice.
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = Fnv64::new();
    h.write(bytes);
    h.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_published_fnv1a_vectors() {
        // Reference values from the FNV specification (Noll).
        assert_eq!(fnv1a(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a(b"foobar"), 0x8594_4171_f738_77ab);
    }

    #[test]
    fn streaming_equals_one_shot() {
        let mut h = Fnv64::new();
        h.write(b"foo");
        h.write(b"bar");
        assert_eq!(h.finish(), fnv1a(b"foobar"));
        let mut h2 = Fnv64::new();
        h2.write_u32(0x6f6f_6661);
        let mut h3 = Fnv64::new();
        h3.write(&[0x61, 0x66, 0x6f, 0x6f]);
        assert_eq!(h2.finish(), h3.finish());
        let mut h4 = Fnv64::new();
        h4.write_u64(1);
        assert_ne!(h4.finish(), fnv1a(b""));
    }
}

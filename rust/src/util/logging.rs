//! Tiny leveled logger (the `log`/`env_logger` crates are unavailable
//! offline). Controlled by `KGSCALE_LOG` (error|warn|info|debug|trace) or
//! programmatically via [`set_level`]. Timestamps are seconds since
//! process start — enough to correlate with benchmark output without
//! pulling in a date library.

use std::io::Write;
use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

impl Level {
    pub fn from_str(s: &str) -> Option<Level> {
        match s.to_ascii_lowercase().as_str() {
            "error" => Some(Level::Error),
            "warn" | "warning" => Some(Level::Warn),
            "info" => Some(Level::Info),
            "debug" => Some(Level::Debug),
            "trace" => Some(Level::Trace),
            _ => None,
        }
    }

    fn tag(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => " WARN",
            Level::Info => " INFO",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

static LEVEL: AtomicU8 = AtomicU8::new(u8::MAX); // MAX = uninitialized
static START: OnceLock<Instant> = OnceLock::new();

fn init_level() -> u8 {
    let lvl = std::env::var("KGSCALE_LOG")
        .ok()
        .and_then(|s| Level::from_str(&s))
        .unwrap_or(Level::Info) as u8;
    LEVEL.store(lvl, Ordering::Relaxed);
    lvl
}

pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

pub fn enabled(level: Level) -> bool {
    let mut cur = LEVEL.load(Ordering::Relaxed);
    if cur == u8::MAX {
        cur = init_level();
    }
    (level as u8) <= cur
}

pub fn log(level: Level, module: &str, msg: std::fmt::Arguments<'_>) {
    if !enabled(level) {
        return;
    }
    let start = START.get_or_init(Instant::now);
    let t = start.elapsed().as_secs_f64();
    let mut err = std::io::stderr().lock();
    let _ = writeln!(err, "[{t:9.3}s {} {module}] {msg}", level.tag());
}

#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Info, module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Warn, module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_error {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Error, module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Debug, module_path!(), format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_parsing() {
        assert_eq!(Level::from_str("info"), Some(Level::Info));
        assert_eq!(Level::from_str("WARN"), Some(Level::Warn));
        assert_eq!(Level::from_str("bogus"), None);
    }

    #[test]
    fn level_ordering_controls_enabled() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Trace);
        assert!(enabled(Level::Debug));
    }
}

//! Timing utilities.
//!
//! Two clocks matter in this codebase:
//!
//! * the **wall clock** (`Stopwatch`) — what actually elapsed on this
//!   machine, used for benchmarks and profiling; and
//! * the **virtual cluster clock** (`train::netsim::VirtualClock`) — the
//!   simulated time of a P-trainer cluster, composed from measured
//!   per-worker compute and a modeled interconnect (see DESIGN.md
//!   "Substitutions").
//!
//! This module provides the wall-clock half plus a scoped-timing helper.

use std::time::{Duration, Instant};

/// Simple resettable stopwatch.
#[derive(Clone, Debug)]
pub struct Stopwatch {
    start: Instant,
}

impl Default for Stopwatch {
    fn default() -> Self {
        Self::new()
    }
}

impl Stopwatch {
    pub fn new() -> Self {
        Self { start: Instant::now() }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }

    /// Read and restart — convenient for phase-by-phase timing.
    pub fn lap_secs(&mut self) -> f64 {
        let t = self.elapsed_secs();
        self.start = Instant::now();
        t
    }
}

/// Time a closure, returning (result, seconds).
pub fn timed<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let sw = Stopwatch::new();
    let out = f();
    (out, sw.elapsed_secs())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stopwatch_is_monotone() {
        let sw = Stopwatch::new();
        let a = sw.elapsed_secs();
        std::thread::sleep(Duration::from_millis(2));
        let b = sw.elapsed_secs();
        assert!(b >= a);
        assert!(b >= 0.002);
    }

    #[test]
    fn lap_resets() {
        let mut sw = Stopwatch::new();
        std::thread::sleep(Duration::from_millis(2));
        let first = sw.lap_secs();
        let second = sw.elapsed_secs();
        assert!(first >= 0.002);
        assert!(second < first);
    }

    #[test]
    fn timed_returns_value_and_duration() {
        let (v, t) = timed(|| {
            std::thread::sleep(Duration::from_millis(1));
            42
        });
        assert_eq!(v, 42);
        assert!(t >= 0.001);
    }
}

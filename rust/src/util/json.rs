//! Minimal JSON reader/writer.
//!
//! `serde_json` is unavailable offline, so the artifact manifest
//! (`artifacts/<cfg>/manifest.json`, written by `python/compile/aot.py`)
//! and experiment result files are handled by this small, strict parser.
//! It supports the full JSON grammar except for exotic number forms
//! (handles ints, floats, exponents) and rejects trailing garbage.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A parsed JSON value. Object keys keep sorted order (BTreeMap) so that
/// serialization is deterministic — experiment outputs diff cleanly.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ----- accessors -------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `get` that fails loudly with the key name — manifest parsing wants
    /// precise errors, not silent `None`s.
    pub fn req(&self, key: &str) -> anyhow::Result<&Json> {
        self.get(key).ok_or_else(|| anyhow::anyhow!("missing key {key:?} in JSON object"))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|x| x as i64)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn req_str(&self, key: &str) -> anyhow::Result<&str> {
        self.req(key)?.as_str().ok_or_else(|| anyhow::anyhow!("key {key:?} is not a string"))
    }

    pub fn req_usize(&self, key: &str) -> anyhow::Result<usize> {
        self.req(key)?.as_usize().ok_or_else(|| anyhow::anyhow!("key {key:?} is not a number"))
    }

    pub fn req_arr(&self, key: &str) -> anyhow::Result<&[Json]> {
        self.req(key)?.as_arr().ok_or_else(|| anyhow::anyhow!("key {key:?} is not an array"))
    }

    // ----- construction helpers --------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    pub fn arr_usize(xs: &[usize]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    // ----- serialization ----------------------------------------------

    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, false);
        out
    }

    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, true);
        out.push('\n');
        out
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        let pad = |out: &mut String, n: usize| {
            if pretty {
                out.push('\n');
                for _ in 0..n {
                    out.push_str("  ");
                }
            }
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 9e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    item.write(out, indent + 1, pretty);
                }
                if !v.is_empty() {
                    pad(out, indent);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    v.write(out, indent + 1, pretty);
                }
                if !m.is_empty() {
                    pad(out, indent);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse a JSON document. Rejects trailing non-whitespace.
pub fn parse(input: &str) -> anyhow::Result<Json> {
    let bytes = input.as_bytes();
    let mut p = Parser { bytes, pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != bytes.len() {
        anyhow::bail!("trailing characters at byte {} of JSON input", p.pos);
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> anyhow::Result<u8> {
        let b = self.peek().ok_or_else(|| anyhow::anyhow!("unexpected end of JSON input"))?;
        self.pos += 1;
        Ok(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> anyhow::Result<()> {
        let got = self.bump()?;
        if got != b {
            anyhow::bail!("expected {:?} at byte {}, got {:?}", b as char, self.pos - 1, got as char);
        }
        Ok(())
    }

    fn value(&mut self) -> anyhow::Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => anyhow::bail!("unexpected {:?} at byte {}", other.map(|c| c as char), self.pos),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> anyhow::Result<Json> {
        for &b in word.as_bytes() {
            self.expect(b)?;
        }
        Ok(v)
    }

    fn object(&mut self) -> anyhow::Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump()? {
                b',' => continue,
                b'}' => return Ok(Json::Obj(map)),
                c => anyhow::bail!("expected ',' or '}}' in object, got {:?}", c as char),
            }
        }
    }

    fn array(&mut self) -> anyhow::Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump()? {
                b',' => continue,
                b']' => return Ok(Json::Arr(items)),
                c => anyhow::bail!("expected ',' or ']' in array, got {:?}", c as char),
            }
        }
    }

    fn string(&mut self) -> anyhow::Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let b = self.bump()?;
            match b {
                b'"' => return Ok(s),
                b'\\' => match self.bump()? {
                    b'"' => s.push('"'),
                    b'\\' => s.push('\\'),
                    b'/' => s.push('/'),
                    b'n' => s.push('\n'),
                    b't' => s.push('\t'),
                    b'r' => s.push('\r'),
                    b'b' => s.push('\u{8}'),
                    b'f' => s.push('\u{c}'),
                    b'u' => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let h = self.bump()?;
                            code = code * 16
                                + (h as char).to_digit(16).ok_or_else(|| {
                                    anyhow::anyhow!("bad unicode escape")
                                })?;
                        }
                        s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    c => anyhow::bail!("bad escape \\{}", c as char),
                },
                _ => {
                    // Re-decode UTF-8: back up and take the full char.
                    self.pos -= 1;
                    let rest = &self.bytes[self.pos..];
                    let st = std::str::from_utf8(rest)
                        .map_err(|_| anyhow::anyhow!("invalid UTF-8 in JSON string"))?;
                    let c = st.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> anyhow::Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        let x: f64 = text.parse().map_err(|_| anyhow::anyhow!("bad number {text:?}"))?;
        Ok(Json::Num(x))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for src in ["null", "true", "false", "0", "-12", "3.5", "1e3", "\"hi\\nthere\""] {
            let v = parse(src).unwrap();
            let back = parse(&v.to_string()).unwrap();
            assert_eq!(v, back, "roundtrip failed for {src}");
        }
    }

    #[test]
    fn nested_structures() {
        let v = parse(r#"{"a": [1, 2, {"b": "c"}], "d": {"e": null}}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("a").unwrap().as_arr().unwrap()[2].req_str("b").unwrap(), "c");
        assert_eq!(v.get("d").unwrap().get("e"), Some(&Json::Null));
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(parse("{} x").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("{\"a\":}").is_err());
    }

    #[test]
    fn unicode_escapes() {
        let v = parse(r#""Aé""#).unwrap();
        assert_eq!(v, Json::Str("Aé".to_string()));
    }

    #[test]
    fn utf8_passthrough() {
        let v = parse("\"héllo → wörld\"").unwrap();
        assert_eq!(v.as_str(), None.or(Some("héllo → wörld")));
        let back = parse(&v.to_string()).unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn pretty_print_parses_back() {
        let v = Json::obj(vec![
            ("name", Json::Str("train_step".into())),
            ("shape", Json::arr_usize(&[64, 32])),
            ("ok", Json::Bool(true)),
        ]);
        let pretty = v.to_string_pretty();
        assert_eq!(parse(&pretty).unwrap(), v);
    }

    #[test]
    fn req_errors_name_the_key() {
        let v = parse("{}").unwrap();
        let err = v.req("missing_key").unwrap_err().to_string();
        assert!(err.contains("missing_key"));
    }
}

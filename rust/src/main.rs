//! `kgscale` — leader entrypoint. See `cli::USAGE`.

use anyhow::{bail, Context, Result};
use kgscale::cli::{Args, USAGE};
use kgscale::config::ExperimentConfig;
use kgscale::model::Manifest;
use kgscale::runtime::Runtime;
use kgscale::train::plan::{plan_buckets, plan_to_json};
use kgscale::train::Trainer;
use kgscale::{eval, experiments, graph, log_info, report};
use std::path::Path;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() {
        eprintln!("{USAGE}");
        std::process::exit(2);
    }
    match run(&argv) {
        Ok(()) => {}
        Err(e) => {
            eprintln!("error: {e:#}");
            std::process::exit(1);
        }
    }
}

fn load_config(args: &Args) -> Result<ExperimentConfig> {
    match args.get("config") {
        Some(path) => ExperimentConfig::from_file(path),
        None => Ok(ExperimentConfig::tiny()),
    }
}

fn artifacts_dir(args: &Args, cfg: &ExperimentConfig) -> std::path::PathBuf {
    match args.get("artifacts") {
        Some(d) => Path::new(d).to_path_buf(),
        None => Path::new(&cfg.runtime.artifacts_dir).join(&cfg.runtime.model_key),
    }
}

fn run(argv: &[String]) -> Result<()> {
    let args = Args::parse(argv)?;
    match args.command.as_str() {
        "help" | "--help" | "-h" => {
            println!("{USAGE}");
            Ok(())
        }
        "info" => cmd_info(&args),
        "generate" => cmd_generate(&args),
        "plan" => cmd_plan(&args),
        "partition" => cmd_partition(&args),
        "train" => cmd_train(&args),
        "experiment" => cmd_experiment(&args),
        other => bail!("unknown command {other:?}\n\n{USAGE}"),
    }
}

fn cmd_info(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let dir = artifacts_dir(args, &cfg);
    args.finish()?;
    println!("config: {} (dataset {} entities, {} relations)", cfg.name, cfg.dataset.entities, cfg.dataset.relations);
    match Manifest::load(&dir) {
        Ok(m) => {
            println!("artifacts: {dir:?} — {} params, {} entries", m.param_count, m.entries.len());
            for e in &m.entries {
                println!("  {e:?}");
            }
        }
        Err(e) => println!("artifacts: not available ({e})"),
    }
    let rt = Runtime::new(&dir);
    match rt {
        Ok(rt) => println!("pjrt: platform={}", rt.platform()),
        Err(e) => println!("pjrt: unavailable ({e})"),
    }
    Ok(())
}

fn cmd_generate(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let out = args.get("out").map(String::from).unwrap_or_else(|| format!("data/{}", cfg.name));
    args.finish()?;
    let g = experiments::dataset(&cfg);
    graph::loader::save(&g, Path::new(&out))?;
    let t = experiments::table1(&[&g]);
    println!("{}", t.to_markdown());
    log_info!("wrote dataset to {out}");
    Ok(())
}

fn cmd_plan(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let trainers = args.get_usize_list("trainers", &[1, 2, 4, 8])?;
    let out = args
        .get("out")
        .map(String::from)
        .unwrap_or_else(|| format!("python/compile/plans/{}.json", cfg.name));
    args.finish()?;
    let g = experiments::dataset(&cfg);
    let plan = plan_buckets(&cfg, &g, &trainers)?;
    let json = plan_to_json(&cfg, &plan);
    if let Some(parent) = Path::new(&out).parent() {
        std::fs::create_dir_all(parent)?;
    }
    std::fs::write(&out, json.to_string_pretty()).with_context(|| format!("writing {out}"))?;
    println!(
        "plan[{}]: {} train buckets, encode ({}, {}), wrote {out}",
        cfg.name,
        plan.train_buckets.len(),
        plan.encode_nodes,
        plan.encode_edges
    );
    Ok(())
}

fn cmd_partition(args: &Args) -> Result<()> {
    let mut cfg = load_config(args)?;
    let p = args.get_usize("partitions", 4)?;
    if let Some(s) = args.get("strategy") {
        cfg.partition.strategy = kgscale::config::PartitionStrategy::from_str(s)?;
    }
    cfg.partition.build_threads = args.get_usize("build-threads", cfg.partition.build_threads)?;
    if let Some(d) = args.get("cache-dir") {
        cfg.partition.cache_dir = d.to_string();
    }
    cfg.validate()?;
    args.finish()?;
    let g = experiments::dataset(&cfg);
    let (t, stats) = experiments::partition_report(&cfg, &g, &[p]);
    println!("{}", t.to_markdown());
    for s in &stats {
        println!("{}", s.summary());
    }
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    let mut cfg = load_config(args)?;
    cfg.train.num_trainers = args.get_usize("trainers", cfg.train.num_trainers)?;
    let epochs = args.get_usize("epochs", cfg.train.epochs)?;
    let eval_every = args.get_usize("eval-every", cfg.train.eval_every)?;
    if let Some(d) = args.get("checkpoint-dir") {
        cfg.train.checkpoint_dir = d.to_string();
    }
    cfg.train.checkpoint_every_epochs =
        args.get_usize("checkpoint-every", cfg.train.checkpoint_every_epochs)?;
    let resume = args.get("resume").map(String::from);
    let dir = artifacts_dir(args, &cfg);
    cfg.validate()?;
    args.finish()?;

    let g = experiments::dataset(&cfg);
    let manifest = Manifest::load(&dir)?;
    let runtime = Runtime::new(&dir)?;
    let filter = eval::FilterIndex::build(&g)?;
    let mut evaluator = eval::Evaluator::new(&manifest, &g, &cfg.eval)?;
    let mut trainer = Trainer::new(cfg.clone(), &g, &runtime, manifest.clone())?;
    let start = match &resume {
        Some(d) => trainer.resume_from_dir(Path::new(d))? as usize,
        None => 0,
    };
    log_info!(
        "training {}: P={} epochs={start}..{epochs} core edges per worker {:?}",
        cfg.name,
        trainer.num_workers(),
        trainer.worker_core_edges()
    );
    for e in start..epochs {
        let rec = trainer.train_epoch()?;
        println!(
            "epoch {e:>3}: loss={:.4} virtual={:.3}s wall={:.3}s (cg {:.4}s, model {:.4}s, sync {:.4}s per batch)",
            rec.mean_loss,
            rec.virtual_secs,
            rec.wall_secs,
            rec.avg_compute_graph,
            rec.avg_gnn_model,
            rec.avg_sync_step
        );
        if rec.fault_recoveries > 0 {
            println!(
                "  recovered {} crash(es): replayed {} steps, {:.3} virtual secs charged",
                rec.fault_recoveries, rec.replayed_steps, rec.recovery_secs
            );
        }
        if eval_every > 0 && (e + 1) % eval_every == 0 {
            let (m, stats) =
                evaluator.evaluate(&runtime, &manifest, &trainer.params, &filter, &g.valid)?;
            trainer.record_eval_stats(m.mrr, &stats);
            println!(
                "  valid MRR={:.4} Hits@1={:.4} Hits@10={:.4} (eval {:.3}s: encode {:.3}s score {:.3}s rank {:.3}s stall {:.3}s overlap {:.2})",
                m.mrr,
                m.hits1,
                m.hits10,
                stats.wall_secs,
                stats.encode_secs,
                stats.score_secs,
                stats.rank_secs,
                stats.rank_stall_secs,
                stats.overlap_efficiency
            );
        }
    }
    let (m, stats) = evaluator.evaluate(&runtime, &manifest, &trainer.params, &filter, &g.test)?;
    println!(
        "TEST: MRR={:.4} Hits@1={:.4} Hits@3={:.4} Hits@10={:.4} ({} queries, {} chunks, eval {:.3}s)",
        m.mrr, m.hits1, m.hits3, m.hits10, m.num_queries, stats.num_chunks, stats.wall_secs
    );
    if cfg.faults.enabled || cfg.train.checkpoint_every_epochs > 0 {
        let label = format!("{} P={}", cfg.name, trainer.num_workers());
        println!("{}", experiments::recovery_table(&trainer.history, &label).to_markdown());
    }
    Ok(())
}

fn cmd_experiment(args: &Args) -> Result<()> {
    let which = args
        .positional
        .first()
        .map(String::as_str)
        .unwrap_or("all")
        .to_string();
    let cfg = load_config(args)?;
    let trainers = args.get_usize_list("trainers", &[1, 2, 4, 8])?;
    let epochs = args.get_usize("epochs", cfg.train.epochs)?;
    let eval_every = args.get_usize("eval-every", 0)?;
    let eval_cap = args.get_usize("eval-cap", 500)?;
    let dir = artifacts_dir(args, &cfg);
    args.finish()?;

    let g = experiments::dataset(&cfg);
    let mut out = String::new();

    // Pure-graph experiments need no artifacts.
    if matches!(which.as_str(), "table1" | "all") {
        out.push_str(&experiments::table1(&[&g]).to_markdown());
    }
    if matches!(which.as_str(), "table2" | "all") {
        out.push_str(&experiments::table2(&cfg, &g, &trainers).to_markdown());
    }
    if matches!(which.as_str(), "fig2" | "all") {
        let f = experiments::fig2(&cfg, &g, 3);
        out.push_str(&f.to_ascii());
        report::save_report(&format!("fig2_{}.csv", cfg.name), &f.to_csv())?;
    }

    if matches!(which.as_str(), "table3" | "table4" | "table5" | "fig6" | "fig7" | "all") {
        let manifest = Manifest::load(&dir)?;
        let runtime = Runtime::new(&dir)?;
        if matches!(which.as_str(), "table3" | "fig6" | "fig7" | "all") {
            let ev = if matches!(which.as_str(), "fig7" | "all") && eval_every == 0 {
                (epochs / 5).max(1)
            } else {
                eval_every
            };
            let (t3, rows) = experiments::table3_sweep(
                &cfg, &g, &runtime, &manifest, &trainers, epochs, ev, eval_cap,
            )?;
            out.push_str(&t3.to_markdown());
            let (f6a, f6b) = experiments::fig6(&rows, &g.name);
            out.push_str(&f6a.to_ascii());
            out.push_str(&f6b.to_markdown());
            let f7 = experiments::fig7(&rows, &g.name);
            out.push_str(&f7.to_ascii());
            out.push_str(&experiments::fig7_table(&rows, &g.name).to_markdown());
            report::save_report(&format!("fig6a_{}.csv", cfg.name), &f6a.to_csv())?;
            report::save_report(&format!("fig7_{}.csv", cfg.name), &f7.to_csv())?;
        }
        if matches!(which.as_str(), "table4" | "all") && cfg.train.batch_edges > 0 {
            out.push_str(
                &experiments::table4(&cfg, &g, &runtime, &manifest, &trainers, epochs)?
                    .to_markdown(),
            );
        }
        if matches!(which.as_str(), "table5" | "all") {
            let p = trainers.iter().copied().find(|&p| p == 4).unwrap_or(trainers[0]);
            out.push_str(
                &experiments::table5(&cfg, &g, &runtime, &manifest, p, epochs)?.to_markdown(),
            );
        }
    }

    println!("{out}");
    let path = report::save_report(&format!("{}_{}.md", which, cfg.name), &out)?;
    log_info!("saved report to {path:?}");
    Ok(())
}

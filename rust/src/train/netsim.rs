//! α-β interconnect model for the simulated cluster.
//!
//! The paper trains on 4 machines × 2 GPUs over 40 Gb Ethernet with Gloo.
//! We reproduce the *timing structure* of that cluster on one core: each
//! worker's compute time is measured for real, and communication costs
//! come from this model (DESIGN.md "Substitutions").
//!
//! Transfer cost of M bytes over one hop: `α + M/β` with α the message
//! latency and β the link bandwidth. Ring AllReduce on P trainers does
//! 2(P−1) steps each moving M/P bytes per link (reduce-scatter +
//! all-gather), so `T_ring = 2(P−1)(α + M/(Pβ))` — the standard
//! bandwidth-optimal bound the paper's §2.2 argument relies on. The
//! parameter-server alternative funnels everything through one endpoint:
//! `T_ps = 2(P−1)·M/β + 2α`, worse by ~P for large M — this asymmetry is
//! exactly why the paper picks AllReduce, and the `allreduce` bench
//! regenerates it.
//!
//! Topology wrinkle (paper §4.4 runs 2 trainers per machine): hops
//! between co-located trainers use `local_bandwidth` (PCIe/NVLink-class).
//! The ring's slowest hop dominates, so the effective β is the cross-node
//! link whenever P > trainers_per_node.

use crate::config::NetworkConfig;

/// Seconds to move `bytes` over one hop of kind `local`.
fn hop_secs(latency_s: f64, bytes: f64, bw_bytes_s: f64) -> f64 {
    latency_s + bytes / bw_bytes_s
}

#[derive(Clone, Debug)]
pub struct NetworkModel {
    latency_s: f64,
    cross_bw: f64,
    local_bw: f64,
    trainers_per_node: usize,
}

impl NetworkModel {
    pub fn new(cfg: &NetworkConfig) -> Self {
        NetworkModel {
            latency_s: cfg.latency_us * 1e-6,
            cross_bw: cfg.bandwidth_gbps * 1e9 / 8.0,
            local_bw: cfg.local_bandwidth_gbps * 1e9 / 8.0,
            trainers_per_node: cfg.trainers_per_node.max(1),
        }
    }

    /// Zero-cost model (used by tests and single-trainer runs).
    pub fn zero() -> Self {
        NetworkModel { latency_s: 0.0, cross_bw: f64::INFINITY, local_bw: f64::INFINITY, trainers_per_node: 1 }
    }

    /// Ring AllReduce of `bytes` across `p` trainers.
    pub fn ring_allreduce_secs(&self, bytes: usize, p: usize) -> f64 {
        if p <= 1 {
            return 0.0;
        }
        // The ring is synchronous: every step waits for its slowest hop.
        let slowest_bw =
            if p > self.trainers_per_node { self.cross_bw } else { self.local_bw };
        let chunk = bytes as f64 / p as f64;
        2.0 * (p - 1) as f64 * hop_secs(self.latency_s, chunk, slowest_bw)
    }

    /// Parameter-server gradient aggregation of `bytes` across `p`
    /// trainers: the server link carries (p−1) gradients in and (p−1)
    /// averaged copies out.
    pub fn param_server_secs(&self, bytes: usize, p: usize) -> f64 {
        if p <= 1 {
            return 0.0;
        }
        2.0 * self.latency_s + 2.0 * (p - 1) as f64 * bytes as f64 / self.cross_bw
    }

    /// One remote fetch (used to cost the *avoided* cross-partition
    /// traffic: global negative sampling, remote neighborhood access).
    pub fn fetch_secs(&self, bytes: usize) -> f64 {
        hop_secs(self.latency_s, bytes as f64, self.cross_bw)
    }

    /// Sparse gradient exchange (DGL-KE style): touched rows differ per
    /// worker, so gradients are ring *all-gathered* (p−1 steps moving
    /// `bytes/p` per link for `bytes` total gathered payload) and summed
    /// locally. `bytes` is the union sparse gradient size — touched rows
    /// × (dim × 4 + 4 index bytes) + the dense tail — so per-step wire
    /// cost scales with the batch's compute graph, not param_count.
    pub fn sparse_allgather_secs(&self, bytes: usize, p: usize) -> f64 {
        if p <= 1 {
            return 0.0;
        }
        let slowest_bw =
            if p > self.trainers_per_node { self.cross_bw } else { self.local_bw };
        let chunk = bytes as f64 / p as f64;
        (p - 1) as f64 * hop_secs(self.latency_s, chunk, slowest_bw)
    }

    /// Sync cost per step for the configured algorithm. For
    /// `GradSync::Sparse` the caller passes the sparse transfer size
    /// (`SparseGrad::transfer_bytes`); the other algorithms take the
    /// dense `param_count * 4`.
    pub fn sync_secs(&self, algo: crate::config::GradSync, bytes: usize, p: usize) -> f64 {
        match algo {
            crate::config::GradSync::Ring => self.ring_allreduce_secs(bytes, p),
            crate::config::GradSync::ParamServer => self.param_server_secs(bytes, p),
            crate::config::GradSync::Sparse => self.sparse_allgather_secs(bytes, p),
            crate::config::GradSync::None => 0.0,
        }
    }

    /// [`sync_secs`](NetworkModel::sync_secs) under transient link
    /// degradation (`train::faults`): the whole α/β cost is inflated by
    /// `factor` for the affected step. `factor = 1.0` is exact (×1.0 is
    /// bitwise identity for finite f64), so an empty fault window costs
    /// nothing in precision.
    pub fn sync_secs_degraded(
        &self,
        algo: crate::config::GradSync,
        bytes: usize,
        p: usize,
        factor: f64,
    ) -> f64 {
        self.sync_secs(algo, bytes, p) * factor
    }
}

/// Virtual cluster clock: composes measured per-worker compute with
/// modeled communication. Synchronous SGD advances all workers to the
/// same barrier each step: `step_time = max_w(compute_w) + sync`.
#[derive(Clone, Debug, Default)]
pub struct VirtualClock {
    now: f64,
}

impl VirtualClock {
    pub fn new() -> Self {
        Self { now: 0.0 }
    }

    /// Advance past a synchronous step.
    pub fn step(&mut self, per_worker_compute_secs: &[f64], sync_secs: f64) -> f64 {
        let max = per_worker_compute_secs.iter().cloned().fold(0.0, f64::max);
        let dt = max + sync_secs;
        self.now += dt;
        dt
    }

    /// Advance by a serial (coordinator-side) cost.
    pub fn advance(&mut self, secs: f64) {
        self.now += secs;
    }

    pub fn now(&self) -> f64 {
        self.now
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ExperimentConfig, GradSync};

    fn model() -> NetworkModel {
        NetworkModel::new(&ExperimentConfig::tiny().network)
    }

    #[test]
    fn single_trainer_costs_nothing() {
        let m = model();
        assert_eq!(m.ring_allreduce_secs(1 << 20, 1), 0.0);
        assert_eq!(m.param_server_secs(1 << 20, 1), 0.0);
    }

    #[test]
    fn ring_beats_param_server_at_scale() {
        let m = model();
        let bytes = 8 << 20; // 8 MB of gradients
        for p in [4, 8, 16] {
            let ring = m.ring_allreduce_secs(bytes, p);
            let ps = m.param_server_secs(bytes, p);
            assert!(
                ring < ps,
                "P={p}: ring {ring:.6}s should beat PS {ps:.6}s (§2.2)"
            );
        }
    }

    #[test]
    fn ring_cost_is_nearly_p_independent_for_large_messages() {
        // 2(P-1)/P * M/β converges to 2M/β: doubling P shouldn't double cost.
        let m = model();
        let bytes = 64 << 20;
        let t4 = m.ring_allreduce_secs(bytes, 4);
        let t8 = m.ring_allreduce_secs(bytes, 8);
        assert!(t8 < t4 * 1.3, "ring scaled badly: {t4:.4} -> {t8:.4}");
    }

    #[test]
    fn local_ring_is_faster_than_cross_node() {
        let m = model();
        // P=2 fits on one node (trainers_per_node=2) -> local bandwidth.
        let local = m.ring_allreduce_secs(8 << 20, 2);
        let mut cfg = ExperimentConfig::tiny().network;
        cfg.trainers_per_node = 1;
        let cross = NetworkModel::new(&cfg).ring_allreduce_secs(8 << 20, 2);
        assert!(local < cross);
    }

    #[test]
    fn sync_dispatch() {
        let m = model();
        assert_eq!(m.sync_secs(GradSync::None, 1 << 20, 8), 0.0);
        assert!(m.sync_secs(GradSync::Ring, 1 << 20, 8) > 0.0);
        assert!(
            m.sync_secs(GradSync::ParamServer, 1 << 20, 8)
                > m.sync_secs(GradSync::Ring, 1 << 20, 8)
        );
    }

    #[test]
    fn sparse_sync_scales_with_touched_bytes_not_params() {
        let m = model();
        let p = 4;
        let dense_bytes = 1_000_000 * 16 * 4; // 1M rows × dim 16
        // A batch-scale touched set: 2k rows × (16 floats + index) + 1 KB tail.
        let sparse_bytes = 2_000 * (16 * 4 + 4) + 1024;
        let dense = m.ring_allreduce_secs(dense_bytes, p);
        let sparse = m.sync_secs(GradSync::Sparse, sparse_bytes, p);
        assert!(
            sparse < dense / 50.0,
            "sparse sync should be orders cheaper: {sparse:.6}s vs {dense:.6}s"
        );
        // Same bytes: all-gather (one phase) beats allreduce (two phases).
        assert!(
            m.sparse_allgather_secs(sparse_bytes, p) < m.ring_allreduce_secs(sparse_bytes, p)
        );
        assert_eq!(m.sparse_allgather_secs(sparse_bytes, 1), 0.0);
    }

    #[test]
    fn degraded_sync_scales_and_factor_one_is_identity() {
        let m = model();
        let base = m.sync_secs(GradSync::Ring, 1 << 20, 8);
        let slow = m.sync_secs_degraded(GradSync::Ring, 1 << 20, 8, 2.0);
        assert_eq!(slow, base * 2.0);
        // factor 1.0 must be bitwise identical — the fault layer leans
        // on this for the disabled ⇒ bit-identical invariant.
        assert_eq!(
            m.sync_secs_degraded(GradSync::Ring, 1 << 20, 8, 1.0).to_bits(),
            base.to_bits()
        );
    }

    #[test]
    fn virtual_clock_composes_max_plus_sync() {
        let mut clk = VirtualClock::new();
        let dt = clk.step(&[0.1, 0.3, 0.2], 0.05);
        assert!((dt - 0.35).abs() < 1e-12);
        clk.advance(0.1);
        assert!((clk.now() - 0.45).abs() < 1e-12);
    }

    #[test]
    fn zero_model_is_free() {
        let m = NetworkModel::zero();
        assert_eq!(m.ring_allreduce_secs(123456, 8), 0.0);
        assert_eq!(m.fetch_secs(1024), 0.0);
    }
}

//! Row-sparse gradient accumulation (DGL-KE-style, Zheng et al. 2020).
//!
//! An edge mini-batch's compute graph touches only the `ent_emb` rows in
//! its `nodes_global` set, and the decoder touches only the `rel_dec`
//! rows gathered by the batch triples' relation ids; the gradient of
//! every other row of either table is exactly zero (a gather's backward
//! is a scatter-add that never reaches them). [`SparseGrad`] exploits
//! this: it stores the touched rows of both tables plus the small dense
//! remainder (projection/bias/basis weights), so per-step
//! accumulate/zero/optimizer cost is O(touched·dim + remainder) instead
//! of O(param_count), and gradient sync can be charged on the bytes that
//! actually move (`NetworkModel::sparse_allgather_secs`).
//!
//! The per-layer relation-coefficient tables stay dense: they are
//! gathered by *edge* relation ids, which in practice cover most
//! relations every batch, so row-sparsity buys nothing there.
//!
//! Accumulation order is preserved per element (workers add in the same
//! sequence the dense path would), so `scatter_into` a zeroed dense
//! vector reproduces the dense accumulator *bit-identically* — the
//! `sparse` gradient mode relies on this to keep dense-Adam semantics
//! while skipping the O(param_count) zero + add on the hot path.

use crate::model::EmbeddingSegment;

/// One row-sparse table's accumulator state.
#[derive(Clone, Debug)]
struct SegAccum {
    seg: EmbeddingSegment,
    /// Touched row ids, in first-touch order.
    rows: Vec<u32>,
    /// Accumulated row gradients, `rows.len() * seg.dim`, parallel to
    /// `rows`.
    row_data: Vec<f32>,
    /// Per table row: slot index + 1 into `rows`, 0 = untouched.
    slot: Vec<u32>,
    /// Per table row: last accumulate call that added it. Relation ids
    /// repeat within a batch (one per triple), and each call must add a
    /// row's gradient exactly once — this stamp dedups within a call
    /// without an O(rows) reset between calls.
    mark: Vec<u64>,
}

impl SegAccum {
    fn new(seg: EmbeddingSegment) -> SegAccum {
        SegAccum {
            seg,
            rows: Vec::new(),
            row_data: Vec::new(),
            slot: vec![0; seg.rows],
            mark: vec![0; seg.rows],
        }
    }

    /// O(touched): only previously-touched slots are reset.
    fn clear(&mut self) {
        for &r in &self.rows {
            self.slot[r as usize] = 0;
        }
        self.rows.clear();
        self.row_data.clear();
    }

    /// Add `flat`'s row `r` (read at the segment's offset) into this
    /// row's accumulator slot, allocating the slot on first touch.
    fn add_row(&mut self, r: u32, flat: &[f32]) {
        let dim = self.seg.dim;
        let ri = r as usize;
        assert!(ri < self.seg.rows, "row id {ri} outside table of {} rows", self.seg.rows);
        let si = if self.slot[ri] == 0 {
            self.rows.push(r);
            self.row_data.resize(self.rows.len() * dim, 0.0);
            self.slot[ri] = self.rows.len() as u32;
            self.rows.len() - 1
        } else {
            (self.slot[ri] - 1) as usize
        };
        let src = &flat[self.seg.offset + ri * dim..self.seg.offset + (ri + 1) * dim];
        for (a, &x) in self.row_data[si * dim..(si + 1) * dim].iter_mut().zip(src) {
            *a += x;
        }
    }
}

/// Row-sparse gradient: touched entity + relation rows, plus the dense
/// remainder.
///
/// The dense remainder covers every flat index outside the two segments,
/// in layout order: `[0, ent.offset)`, then `[ent.end, rel.offset)`,
/// then `[rel.end, param_count)`. An absent segment is represented empty
/// (the entity table at offset 0, the relation table at `param_count`),
/// so with neither segment the whole vector is remainder and the
/// representation degrades gracefully to dense.
#[derive(Clone, Debug)]
pub struct SparseGrad {
    ent: SegAccum,
    rel: SegAccum,
    param_count: usize,
    /// Dense remainder accumulator (`param_count - ent.len - rel.len`).
    dense: Vec<f32>,
    /// Monotonic accumulate-call counter driving `SegAccum::mark`.
    calls: u64,
}

impl SparseGrad {
    /// Entity-table-only sparsity: `seg = None` (no trainable embedding
    /// table) puts every parameter in the dense remainder.
    pub fn new(seg: Option<EmbeddingSegment>, param_count: usize) -> Self {
        Self::with_relations(seg, None, param_count)
    }

    /// Row-sparsity over both the entity table and the relation-decoder
    /// table. Segments must not overlap and the entity table must come
    /// first in the flat layout (as `model::params` lays them out);
    /// either may be `None`.
    pub fn with_relations(
        ent: Option<EmbeddingSegment>,
        rel: Option<EmbeddingSegment>,
        param_count: usize,
    ) -> Self {
        let ent = ent.unwrap_or(EmbeddingSegment { offset: 0, rows: 0, dim: 0 });
        // An absent relation segment sits empty at the end of the vector
        // so the three-piece remainder math needs no special cases.
        let rel = rel.unwrap_or(EmbeddingSegment { offset: param_count, rows: 0, dim: 0 });
        assert!(ent.end() <= param_count, "embedding segment exceeds param vector");
        assert!(rel.end() <= param_count, "relation segment exceeds param vector");
        assert!(ent.end() <= rel.offset, "segments must be ordered ent before rel");
        SparseGrad {
            ent: SegAccum::new(ent),
            rel: SegAccum::new(rel),
            param_count,
            dense: vec![0.0; param_count - ent.len() - rel.len()],
            calls: 0,
        }
    }

    /// The entity-embedding segment (empty if absent).
    pub fn segment(&self) -> EmbeddingSegment {
        self.ent.seg
    }

    /// The relation-decoder segment (empty if absent).
    pub fn relation_segment(&self) -> EmbeddingSegment {
        self.rel.seg
    }

    pub fn param_count(&self) -> usize {
        self.param_count
    }

    /// Touched entity row ids (first-touch order).
    pub fn touched(&self) -> &[u32] {
        &self.ent.rows
    }

    pub fn touched_rows(&self) -> usize {
        self.ent.rows.len()
    }

    /// Accumulated gradient of the i-th touched entity row.
    pub fn row(&self, i: usize) -> &[f32] {
        &self.ent.row_data[i * self.ent.seg.dim..(i + 1) * self.ent.seg.dim]
    }

    /// Touched relation row ids (first-touch order).
    pub fn touched_rels(&self) -> &[u32] {
        &self.rel.rows
    }

    pub fn touched_rel_rows(&self) -> usize {
        self.rel.rows.len()
    }

    /// Accumulated gradient of the i-th touched relation row.
    pub fn rel_row(&self, i: usize) -> &[f32] {
        &self.rel.row_data[i * self.rel.seg.dim..(i + 1) * self.rel.seg.dim]
    }

    /// Dense remainder accumulator.
    pub fn dense(&self) -> &[f32] {
        &self.dense
    }

    /// Flat parameter index of remainder element `i` (remainder indices
    /// skip over both segments).
    pub fn dense_param_index(&self, i: usize) -> usize {
        let head = self.ent.seg.offset;
        let mid_end = head + (self.rel.seg.offset - self.ent.seg.end());
        if i < head {
            i
        } else if i < mid_end {
            i + self.ent.seg.len()
        } else {
            i + self.ent.seg.len() + self.rel.seg.len()
        }
    }

    /// Reset for the next synchronous step. O(touched + remainder): only
    /// the previously-touched slots and the small remainder are cleared
    /// — no O(param_count) `fill(0.0)`.
    pub fn clear(&mut self) {
        self.ent.clear();
        self.rel.clear();
        self.dense.fill(0.0);
    }

    /// Entity-only accumulation (back-compat path for callers without
    /// relation ids). Refuses to run with a relation segment configured:
    /// the relation rows' gradients would be silently dropped.
    pub fn accumulate(&mut self, nodes_global: &[u32], flat: &[f32]) {
        assert!(
            self.rel.seg.is_empty(),
            "relation-sparse accumulator requires accumulate_with_rels"
        );
        self.accumulate_with_rels(nodes_global, &[], flat);
    }

    /// Accumulate one worker batch's flat gradient readback: adds the
    /// `nodes_global` entity rows, the (deduplicated) `rels` relation
    /// rows, and the dense remainder. `flat` must be a full
    /// `param_count` gradient whose segment rows outside the touched
    /// sets are exactly zero (guaranteed by the gather/scatter backward;
    /// verified by the gradient-path equivalence tests). `nodes_global`
    /// is distinct by construction; `rels` may repeat (one id per
    /// triple) — each distinct row is added exactly once per call, which
    /// is what the dense elementwise add does.
    pub fn accumulate_with_rels(&mut self, nodes_global: &[u32], rels: &[i32], flat: &[f32]) {
        assert_eq!(flat.len(), self.param_count, "gradient length mismatch");
        self.calls += 1;
        if self.ent.seg.dim > 0 {
            for &g in nodes_global {
                self.ent.add_row(g, flat);
            }
        }
        if self.rel.seg.dim > 0 {
            for &r in rels {
                let ri = r as usize; // relation ids are non-negative
                if self.rel.mark[ri] == self.calls {
                    continue;
                }
                self.rel.mark[ri] = self.calls;
                self.rel.add_row(r as u32, flat);
            }
        }
        // Dense remainder: three pieces around the two segments.
        let head = self.ent.seg.offset;
        let mid = self.rel.seg.offset - self.ent.seg.end();
        for (a, &x) in self.dense[..head].iter_mut().zip(&flat[..head]) {
            *a += x;
        }
        for (a, &x) in self.dense[head..head + mid]
            .iter_mut()
            .zip(&flat[self.ent.seg.end()..self.rel.seg.offset])
        {
            *a += x;
        }
        for (a, &x) in self.dense[head + mid..].iter_mut().zip(&flat[self.rel.seg.end()..]) {
            *a += x;
        }
    }

    /// Scale every accumulated value (gradient averaging). Elementwise,
    /// so bit-identical to scaling the dense accumulator.
    pub fn scale(&mut self, factor: f32) {
        for x in self.ent.row_data.iter_mut() {
            *x *= factor;
        }
        for x in self.rel.row_data.iter_mut() {
            *x *= factor;
        }
        for x in self.dense.iter_mut() {
            *x *= factor;
        }
    }

    /// Write the accumulated gradient into a dense vector whose entries
    /// are all zero (untouched segment rows stay exactly 0.0). Undo
    /// with [`clear_scatter`](Self::clear_scatter) to keep the target
    /// reusable without an O(param_count) refill.
    pub fn scatter_into(&self, out: &mut [f32]) {
        assert_eq!(out.len(), self.param_count);
        for sa in [&self.ent, &self.rel] {
            let dim = sa.seg.dim;
            for (i, &r) in sa.rows.iter().enumerate() {
                let o = sa.seg.offset + r as usize * dim;
                out[o..o + dim].copy_from_slice(&sa.row_data[i * dim..(i + 1) * dim]);
            }
        }
        let head = self.ent.seg.offset;
        let mid = self.rel.seg.offset - self.ent.seg.end();
        out[..head].copy_from_slice(&self.dense[..head]);
        out[self.ent.seg.end()..self.rel.seg.offset]
            .copy_from_slice(&self.dense[head..head + mid]);
        out[self.rel.seg.end()..].copy_from_slice(&self.dense[head + mid..]);
    }

    /// Zero exactly the entries [`scatter_into`](Self::scatter_into)
    /// wrote, restoring an all-zero dense vector in O(touched +
    /// remainder).
    pub fn clear_scatter(&self, out: &mut [f32]) {
        assert_eq!(out.len(), self.param_count);
        for sa in [&self.ent, &self.rel] {
            let dim = sa.seg.dim;
            for &r in &sa.rows {
                let o = sa.seg.offset + r as usize * dim;
                out[o..o + dim].fill(0.0);
            }
        }
        out[..self.ent.seg.offset].fill(0.0);
        out[self.ent.seg.end()..self.rel.seg.offset].fill(0.0);
        out[self.rel.seg.end()..].fill(0.0);
    }

    /// Bytes a worker actually puts on the wire to share this gradient:
    /// touched rows × dim × 4 (row payload) + 4 per row index, for both
    /// tables, + the dense remainder — versus `param_count × 4` for a
    /// dense sync.
    pub fn transfer_bytes(&self) -> usize {
        self.ent.rows.len() * (self.ent.seg.dim * 4 + 4)
            + self.rel.rows.len() * (self.rel.seg.dim * 4 + 4)
            + self.dense.len() * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn seg(offset: usize, rows: usize, dim: usize) -> EmbeddingSegment {
        EmbeddingSegment { offset, rows, dim }
    }

    /// Dense reference accumulator for equivalence checks.
    fn dense_accumulate(acc: &mut [f32], flat: &[f32]) {
        for (a, &x) in acc.iter_mut().zip(flat) {
            *a += x;
        }
    }

    /// A flat gradient touching only `touched` rows of a (rows×dim)
    /// table at `offset`, with a nonzero remainder.
    fn flat_grad(param_count: usize, s: EmbeddingSegment, touched: &[u32], salt: f32) -> Vec<f32> {
        let mut g = vec![0.0f32; param_count];
        for &r in touched {
            for d in 0..s.dim {
                g[s.offset + r as usize * s.dim + d] = salt + r as f32 * 0.25 + d as f32 * 0.125;
            }
        }
        for i in 0..s.offset {
            g[i] = salt * 0.5 + i as f32;
        }
        for i in s.end()..param_count {
            g[i] = -salt + (i - s.end()) as f32 * 0.0625;
        }
        g
    }

    #[test]
    fn sparse_accumulate_matches_dense_bitwise() {
        let s = seg(4, 10, 3);
        let pc = 4 + 30 + 5;
        let mut sg = SparseGrad::new(Some(s), pc);
        let mut dense = vec![0.0f32; pc];
        // Two "workers" with overlapping touched sets, then averaging.
        let g1 = flat_grad(pc, s, &[2, 7, 3], 1.0);
        let g2 = flat_grad(pc, s, &[7, 9], -0.375);
        sg.accumulate(&[2, 7, 3], &g1);
        sg.accumulate(&[7, 9], &g2);
        dense_accumulate(&mut dense, &g1);
        dense_accumulate(&mut dense, &g2);
        let inv = 1.0f32 / 3.0;
        sg.scale(inv);
        for x in dense.iter_mut() {
            *x *= inv;
        }
        let mut out = vec![0.0f32; pc];
        sg.scatter_into(&mut out);
        assert_eq!(out, dense, "sparse scatter must be bit-identical to dense path");
        assert_eq!(sg.touched_rows(), 4); // {2, 7, 3, 9}
        sg.clear_scatter(&mut out);
        assert!(out.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn clear_is_complete_and_reusable() {
        let s = seg(0, 6, 2);
        let pc = 12 + 3;
        let mut sg = SparseGrad::new(Some(s), pc);
        let g = flat_grad(pc, s, &[1, 4], 2.0);
        sg.accumulate(&[1, 4], &g);
        assert_eq!(sg.touched_rows(), 2);
        sg.clear();
        assert_eq!(sg.touched_rows(), 0);
        assert!(sg.dense().iter().all(|&x| x == 0.0));
        // Re-accumulate a different set: old slots must not leak.
        let g2 = flat_grad(pc, s, &[0, 4], -1.0);
        sg.accumulate(&[0, 4], &g2);
        assert_eq!(sg.touched(), &[0, 4]);
        let mut out = vec![0.0f32; pc];
        sg.scatter_into(&mut out);
        let mut dense = vec![0.0f32; pc];
        dense_accumulate(&mut dense, &g2);
        assert_eq!(out, dense);
    }

    #[test]
    fn no_segment_degrades_to_dense_remainder() {
        let pc = 9;
        let mut sg = SparseGrad::new(None, pc);
        let g: Vec<f32> = (0..pc).map(|i| i as f32).collect();
        sg.accumulate(&[], &g);
        assert_eq!(sg.touched_rows(), 0);
        assert_eq!(sg.dense(), g.as_slice());
        assert_eq!(sg.dense_param_index(5), 5);
        assert_eq!(sg.transfer_bytes(), pc * 4);
    }

    #[test]
    fn transfer_bytes_counts_rows_indices_and_tail() {
        let s = seg(0, 100, 8);
        let pc = 800 + 40;
        let mut sg = SparseGrad::new(Some(s), pc);
        let g = flat_grad(pc, s, &[5, 50, 99], 1.0);
        sg.accumulate(&[5, 50, 99], &g);
        // 3 rows × (8 floats + 1 index) × 4B + 40-float tail.
        assert_eq!(sg.transfer_bytes(), 3 * (8 * 4 + 4) + 40 * 4);
        assert!(sg.transfer_bytes() < pc * 4, "sparse must beat dense bytes");
    }

    #[test]
    fn dense_param_index_skips_segment() {
        let sg = SparseGrad::new(Some(seg(4, 10, 3)), 39);
        assert_eq!(sg.dense_param_index(0), 0);
        assert_eq!(sg.dense_param_index(3), 3);
        // Remainder index 4 is the first tail element, after the 30-float
        // segment ending at flat index 34.
        assert_eq!(sg.dense_param_index(4), 34);
        assert_eq!(sg.dense_param_index(8), 38);
    }

    /// A two-segment layout mirroring the real one: ent table first, a
    /// dense middle (layer weights), the rel table at the end.
    fn two_seg() -> (EmbeddingSegment, EmbeddingSegment, usize) {
        let ent = seg(0, 6, 4); // [0, 24)
        let rel = seg(30, 3, 2); // [30, 36), dense mid = [24, 30)
        (ent, rel, 36)
    }

    /// Build a flat gradient for the two-segment layout: entity rows
    /// from `ent_touched`, relation rows from `rel_touched`, every
    /// non-segment index nonzero.
    fn two_seg_grad(ent_touched: &[u32], rel_touched: &[i32], salt: f32) -> Vec<f32> {
        let (ent, rel, pc) = two_seg();
        let mut g = vec![0.0f32; pc];
        for &r in ent_touched {
            for d in 0..ent.dim {
                g[ent.offset + r as usize * ent.dim + d] = salt + r as f32 + d as f32 * 0.5;
            }
        }
        for &r in rel_touched {
            for d in 0..rel.dim {
                g[rel.offset + r as usize * rel.dim + d] = -salt + r as f32 * 0.25 + d as f32;
            }
        }
        for i in ent.end()..rel.offset {
            g[i] = salt * 0.125 + i as f32;
        }
        g
    }

    #[test]
    fn relation_segment_matches_dense_bitwise() {
        let (ent, rel, pc) = two_seg();
        let mut sg = SparseGrad::with_relations(Some(ent), Some(rel), pc);
        let mut dense = vec![0.0f32; pc];
        // Relation ids repeat within a call (one per triple) — the
        // accumulator must add each touched rel row exactly once per
        // call, like the dense elementwise add does.
        let g1 = two_seg_grad(&[1, 3], &[0, 2], 1.0);
        let g2 = two_seg_grad(&[3, 5], &[2], -0.5);
        sg.accumulate_with_rels(&[1, 3], &[0, 2, 0, 2, 2], &g1);
        sg.accumulate_with_rels(&[3, 5], &[2, 2], &g2);
        dense_accumulate(&mut dense, &g1);
        dense_accumulate(&mut dense, &g2);
        let inv = 0.5f32;
        sg.scale(inv);
        for x in dense.iter_mut() {
            *x *= inv;
        }
        let mut out = vec![0.0f32; pc];
        sg.scatter_into(&mut out);
        assert_eq!(out, dense, "two-segment scatter must match dense bitwise");
        assert_eq!(sg.touched(), &[1, 3, 5]);
        assert_eq!(sg.touched_rels(), &[0, 2]);
        sg.clear_scatter(&mut out);
        assert!(out.iter().all(|&x| x == 0.0));
        // clear() resets the rel side too (fresh marks next step).
        sg.clear();
        assert_eq!(sg.touched_rel_rows(), 0);
        sg.accumulate_with_rels(&[0], &[1], &two_seg_grad(&[0], &[1], 2.0));
        assert_eq!(sg.touched_rels(), &[1]);
    }

    #[test]
    fn relation_rows_enter_transfer_bytes() {
        let (ent, rel, pc) = two_seg();
        let mut sg = SparseGrad::with_relations(Some(ent), Some(rel), pc);
        sg.accumulate_with_rels(&[2], &[1, 1], &two_seg_grad(&[2], &[1], 1.0));
        // 1 ent row (4 floats + idx) + 1 rel row (2 floats + idx) + the
        // 6-float dense middle.
        assert_eq!(sg.transfer_bytes(), (4 * 4 + 4) + (2 * 4 + 4) + 6 * 4);
    }

    #[test]
    fn dense_param_index_skips_both_segments() {
        let (ent, rel, pc) = two_seg();
        let sg = SparseGrad::with_relations(Some(ent), Some(rel), pc);
        assert_eq!(sg.dense().len(), 6);
        // The remainder is exactly the dense middle [24, 30).
        for i in 0..6 {
            assert_eq!(sg.dense_param_index(i), 24 + i);
        }
        // Rel-only layout: head remainder precedes the segment.
        let sg2 = SparseGrad::with_relations(None, Some(seg(4, 2, 3)), 12);
        assert_eq!(sg2.dense().len(), 6);
        assert_eq!(sg2.dense_param_index(0), 0);
        assert_eq!(sg2.dense_param_index(3), 3);
        assert_eq!(sg2.dense_param_index(4), 10);
        assert_eq!(sg2.dense_param_index(5), 11);
    }

    #[test]
    #[should_panic(expected = "accumulate_with_rels")]
    fn entity_only_accumulate_refuses_relation_segment() {
        let (ent, rel, pc) = two_seg();
        let mut sg = SparseGrad::with_relations(Some(ent), Some(rel), pc);
        let g = two_seg_grad(&[0], &[0], 1.0);
        sg.accumulate(&[0], &g);
    }
}

//! Row-sparse gradient accumulation (DGL-KE-style, Zheng et al. 2020).
//!
//! An edge mini-batch's compute graph touches only the `ent_emb` rows in
//! its `nodes_global` set; the gradient of every other embedding row is
//! exactly zero (the gather's backward is a scatter-add that never
//! reaches them). [`SparseGrad`] exploits this: it stores the touched
//! rows plus the small dense non-embedding remainder, so per-step
//! accumulate/zero/optimizer cost is O(touched·dim + tail) instead of
//! O(param_count), and gradient sync can be charged on the bytes that
//! actually move (`NetworkModel::sparse_allgather_secs`).
//!
//! Accumulation order is preserved per element (workers add in the same
//! sequence the dense path would), so `scatter_into` a zeroed dense
//! vector reproduces the dense accumulator *bit-identically* — the
//! `sparse` gradient mode relies on this to keep dense-Adam semantics
//! while skipping the O(param_count) zero + add on the hot path.

use crate::model::EmbeddingSegment;

/// Row-sparse gradient: touched embedding rows + dense remainder.
///
/// The dense remainder covers every flat index outside the embedding
/// segment: `[0, offset)` followed by `[offset + rows·dim, param_count)`.
/// With no embedding segment (provided-features mode) the whole vector is
/// remainder and the representation degrades gracefully to dense.
#[derive(Clone, Debug)]
pub struct SparseGrad {
    seg: EmbeddingSegment,
    param_count: usize,
    /// Touched global row ids, in first-touch order.
    rows: Vec<u32>,
    /// Accumulated row gradients, `rows.len() * seg.dim`, parallel to
    /// `rows`.
    row_data: Vec<f32>,
    /// Dense remainder accumulator (`param_count - seg.len()` floats).
    dense: Vec<f32>,
    /// Per embedding row: slot index + 1 into `rows`, 0 = untouched.
    slot: Vec<u32>,
}

impl SparseGrad {
    /// `seg = None` (no trainable embedding table) puts every parameter
    /// in the dense remainder.
    pub fn new(seg: Option<EmbeddingSegment>, param_count: usize) -> Self {
        let seg = seg.unwrap_or(EmbeddingSegment { offset: 0, rows: 0, dim: 0 });
        assert!(seg.end() <= param_count, "embedding segment exceeds param vector");
        SparseGrad {
            seg,
            param_count,
            rows: Vec::new(),
            row_data: Vec::new(),
            dense: vec![0.0; param_count - seg.len()],
            slot: vec![0; seg.rows],
        }
    }

    pub fn segment(&self) -> EmbeddingSegment {
        self.seg
    }

    pub fn param_count(&self) -> usize {
        self.param_count
    }

    /// Touched global row ids (first-touch order).
    pub fn touched(&self) -> &[u32] {
        &self.rows
    }

    pub fn touched_rows(&self) -> usize {
        self.rows.len()
    }

    /// Accumulated gradient of the i-th touched row.
    pub fn row(&self, i: usize) -> &[f32] {
        &self.row_data[i * self.seg.dim..(i + 1) * self.seg.dim]
    }

    /// Dense remainder accumulator.
    pub fn dense(&self) -> &[f32] {
        &self.dense
    }

    /// Flat parameter index of remainder element `i` (remainder indices
    /// skip over the embedding segment).
    pub fn dense_param_index(&self, i: usize) -> usize {
        if i < self.seg.offset {
            i
        } else {
            i + self.seg.len()
        }
    }

    /// Reset for the next synchronous step. O(touched + tail): only the
    /// previously-touched slots and the small remainder are cleared — no
    /// O(param_count) `fill(0.0)`.
    pub fn clear(&mut self) {
        for &r in &self.rows {
            self.slot[r as usize] = 0;
        }
        self.rows.clear();
        self.row_data.clear();
        self.dense.fill(0.0);
    }

    /// Accumulate one worker batch's flat gradient readback: adds the
    /// `nodes_global` embedding rows and the whole dense remainder.
    /// `flat` must be a full `param_count` gradient whose embedding rows
    /// outside `nodes_global` are exactly zero (guaranteed by the
    /// gather/scatter backward; verified by the gradient-path equivalence
    /// tests).
    pub fn accumulate(&mut self, nodes_global: &[u32], flat: &[f32]) {
        assert_eq!(flat.len(), self.param_count, "gradient length mismatch");
        let dim = self.seg.dim;
        if dim > 0 {
            for &g in nodes_global {
                let gi = g as usize;
                assert!(gi < self.seg.rows, "node id {gi} outside embedding table");
                let si = if self.slot[gi] == 0 {
                    self.rows.push(g);
                    self.row_data.resize(self.rows.len() * dim, 0.0);
                    self.slot[gi] = self.rows.len() as u32;
                    self.rows.len() - 1
                } else {
                    (self.slot[gi] - 1) as usize
                };
                let src = &flat[self.seg.offset + gi * dim..self.seg.offset + (gi + 1) * dim];
                for (a, &x) in self.row_data[si * dim..(si + 1) * dim].iter_mut().zip(src) {
                    *a += x;
                }
            }
        }
        // Dense remainder: head [0, offset) then tail [end, param_count).
        let (head, tail) = self.dense.split_at_mut(self.seg.offset);
        for (a, &x) in head.iter_mut().zip(&flat[..self.seg.offset]) {
            *a += x;
        }
        for (a, &x) in tail.iter_mut().zip(&flat[self.seg.end()..]) {
            *a += x;
        }
    }

    /// Scale every accumulated value (gradient averaging). Elementwise,
    /// so bit-identical to scaling the dense accumulator.
    pub fn scale(&mut self, factor: f32) {
        for x in self.row_data.iter_mut() {
            *x *= factor;
        }
        for x in self.dense.iter_mut() {
            *x *= factor;
        }
    }

    /// Write the accumulated gradient into a dense vector whose entries
    /// are all zero (untouched embedding rows stay exactly 0.0). Undo
    /// with [`clear_scatter`](Self::clear_scatter) to keep the target
    /// reusable without an O(param_count) refill.
    pub fn scatter_into(&self, out: &mut [f32]) {
        assert_eq!(out.len(), self.param_count);
        let dim = self.seg.dim;
        for (i, &r) in self.rows.iter().enumerate() {
            let o = self.seg.offset + r as usize * dim;
            out[o..o + dim].copy_from_slice(&self.row_data[i * dim..(i + 1) * dim]);
        }
        out[..self.seg.offset].copy_from_slice(&self.dense[..self.seg.offset]);
        out[self.seg.end()..].copy_from_slice(&self.dense[self.seg.offset..]);
    }

    /// Zero exactly the entries [`scatter_into`](Self::scatter_into)
    /// wrote, restoring an all-zero dense vector in O(touched + tail).
    pub fn clear_scatter(&self, out: &mut [f32]) {
        assert_eq!(out.len(), self.param_count);
        let dim = self.seg.dim;
        for &r in &self.rows {
            let o = self.seg.offset + r as usize * dim;
            out[o..o + dim].fill(0.0);
        }
        out[..self.seg.offset].fill(0.0);
        out[self.seg.end()..].fill(0.0);
    }

    /// Bytes a worker actually puts on the wire to share this gradient:
    /// touched rows × dim × 4 (row payload) + 4 per row index + the dense
    /// remainder — versus `param_count × 4` for a dense sync.
    pub fn transfer_bytes(&self) -> usize {
        self.rows.len() * (self.seg.dim * 4 + 4) + self.dense.len() * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn seg(offset: usize, rows: usize, dim: usize) -> EmbeddingSegment {
        EmbeddingSegment { offset, rows, dim }
    }

    /// Dense reference accumulator for equivalence checks.
    fn dense_accumulate(acc: &mut [f32], flat: &[f32]) {
        for (a, &x) in acc.iter_mut().zip(flat) {
            *a += x;
        }
    }

    /// A flat gradient touching only `touched` rows of a (rows×dim)
    /// table at `offset`, with a nonzero remainder.
    fn flat_grad(
        param_count: usize,
        s: EmbeddingSegment,
        touched: &[u32],
        salt: f32,
    ) -> Vec<f32> {
        let mut g = vec![0.0f32; param_count];
        for &r in touched {
            for d in 0..s.dim {
                g[s.offset + r as usize * s.dim + d] =
                    salt + r as f32 * 0.25 + d as f32 * 0.125;
            }
        }
        for i in 0..s.offset {
            g[i] = salt * 0.5 + i as f32;
        }
        for i in s.end()..param_count {
            g[i] = -salt + (i - s.end()) as f32 * 0.0625;
        }
        g
    }

    #[test]
    fn sparse_accumulate_matches_dense_bitwise() {
        let s = seg(4, 10, 3);
        let pc = 4 + 30 + 5;
        let mut sg = SparseGrad::new(Some(s), pc);
        let mut dense = vec![0.0f32; pc];
        // Two "workers" with overlapping touched sets, then averaging.
        let g1 = flat_grad(pc, s, &[2, 7, 3], 1.0);
        let g2 = flat_grad(pc, s, &[7, 9], -0.375);
        sg.accumulate(&[2, 7, 3], &g1);
        sg.accumulate(&[7, 9], &g2);
        dense_accumulate(&mut dense, &g1);
        dense_accumulate(&mut dense, &g2);
        let inv = 1.0f32 / 3.0;
        sg.scale(inv);
        for x in dense.iter_mut() {
            *x *= inv;
        }
        let mut out = vec![0.0f32; pc];
        sg.scatter_into(&mut out);
        assert_eq!(out, dense, "sparse scatter must be bit-identical to dense path");
        assert_eq!(sg.touched_rows(), 4); // {2, 7, 3, 9}
        sg.clear_scatter(&mut out);
        assert!(out.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn clear_is_complete_and_reusable() {
        let s = seg(0, 6, 2);
        let pc = 12 + 3;
        let mut sg = SparseGrad::new(Some(s), pc);
        let g = flat_grad(pc, s, &[1, 4], 2.0);
        sg.accumulate(&[1, 4], &g);
        assert_eq!(sg.touched_rows(), 2);
        sg.clear();
        assert_eq!(sg.touched_rows(), 0);
        assert!(sg.dense().iter().all(|&x| x == 0.0));
        // Re-accumulate a different set: old slots must not leak.
        let g2 = flat_grad(pc, s, &[0, 4], -1.0);
        sg.accumulate(&[0, 4], &g2);
        assert_eq!(sg.touched(), &[0, 4]);
        let mut out = vec![0.0f32; pc];
        sg.scatter_into(&mut out);
        let mut dense = vec![0.0f32; pc];
        dense_accumulate(&mut dense, &g2);
        assert_eq!(out, dense);
    }

    #[test]
    fn no_segment_degrades_to_dense_remainder() {
        let pc = 9;
        let mut sg = SparseGrad::new(None, pc);
        let g: Vec<f32> = (0..pc).map(|i| i as f32).collect();
        sg.accumulate(&[], &g);
        assert_eq!(sg.touched_rows(), 0);
        assert_eq!(sg.dense(), g.as_slice());
        assert_eq!(sg.dense_param_index(5), 5);
        assert_eq!(sg.transfer_bytes(), pc * 4);
    }

    #[test]
    fn transfer_bytes_counts_rows_indices_and_tail() {
        let s = seg(0, 100, 8);
        let pc = 800 + 40;
        let mut sg = SparseGrad::new(Some(s), pc);
        let g = flat_grad(pc, s, &[5, 50, 99], 1.0);
        sg.accumulate(&[5, 50, 99], &g);
        // 3 rows × (8 floats + 1 index) × 4B + 40-float tail.
        assert_eq!(sg.transfer_bytes(), 3 * (8 * 4 + 4) + 40 * 4);
        assert!(sg.transfer_bytes() < pc * 4, "sparse must beat dense bytes");
    }

    #[test]
    fn dense_param_index_skips_segment() {
        let sg = SparseGrad::new(Some(seg(4, 10, 3)), 39);
        assert_eq!(sg.dense_param_index(0), 0);
        assert_eq!(sg.dense_param_index(3), 3);
        // Remainder index 4 is the first tail element, after the 30-float
        // segment ending at flat index 34.
        assert_eq!(sg.dense_param_index(4), 34);
        assert_eq!(sg.dense_param_index(8), 38);
    }
}

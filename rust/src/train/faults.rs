//! Deterministic fault injection for the simulated cluster.
//!
//! The virtual cluster in `train::netsim` models *timing* only — every
//! run is failure-free. This module layers a seeded fault schedule on
//! top so the trainer can exercise (and price, on the `VirtualClock`)
//! the recovery machinery a real multi-machine deployment needs.
//!
//! # Event model
//!
//! Three event kinds, all expressed against the per-epoch step grid of
//! `P` workers × `steps` synchronous steps:
//!
//! - **Worker crash** (`CrashEvent`): worker `wid` dies at step `step`.
//!   The synchronous barrier detects the dead replica after the step's
//!   gradient exchange, restores model + optimizer state from the last
//!   checkpoint, and deterministically replays the lost steps (the
//!   per-(epoch, wid) RNG streams make the replay bit-exact). At most
//!   one crash is scheduled per epoch — the first Bernoulli success in
//!   step-major (step, wid) order — because recovery resets the epoch
//!   tail anyway.
//! - **Straggler window** (`StragglerWindow`): worker `wid`'s measured
//!   compute time is multiplied by `factor` (≥ 1) for steps in
//!   `[start, end)`. Under the synchronous barrier the whole cluster
//!   waits, so one slow replica inflates every step in the window.
//! - **Link degradation** (`LinkWindow`): the modeled gradient-sync
//!   time (α/β cost from `NetworkModel`) is multiplied by `factor` for
//!   steps in `[start, end)` — a transient slow interconnect.
//!
//! # Determinism contract
//!
//! The schedule for epoch `e` is a pure function of
//! (`faults.seed`, `e`, `P`, `steps`): a dedicated
//! `Rng::seeded(seed + e * GOLDEN)` stream, *disjoint from every
//! training stream* (workers draw from per-(epoch, wid) sampler seeds;
//! the fault stream never touches them). Draw order is fixed —
//! stragglers (one Bernoulli + window per worker), then link (one
//! Bernoulli + window), then the crash scan — so enabling one event
//! kind never shifts another kind's draws. Re-running a config
//! reproduces the identical fault sequence, which is what makes the
//! crash-recovery e2e invariant (recovered trajectory == fault-free
//! trajectory) testable at all. With `faults.enabled = false` the
//! trainer never constructs a plan and the hot path multiplies by
//! nothing — bit-identical to the pre-fault-layer code.

use crate::config::FaultsConfig;
use crate::util::rng::Rng;

/// Worker `wid` dies at step `step`; detected at that step's barrier.
#[derive(Clone, Debug, PartialEq)]
pub struct CrashEvent {
    pub step: usize,
    pub wid: usize,
}

/// Worker `wid` computes `factor`× slower for steps in `[start, end)`.
#[derive(Clone, Debug, PartialEq)]
pub struct StragglerWindow {
    pub wid: usize,
    pub start: usize,
    pub end: usize,
    pub factor: f64,
}

/// Gradient-sync cost is `factor`× for steps in `[start, end)`.
#[derive(Clone, Debug, PartialEq)]
pub struct LinkWindow {
    pub start: usize,
    pub end: usize,
    pub factor: f64,
}

/// The fault schedule for one epoch, fully materialized up front.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct EpochFaults {
    pub crash: Option<CrashEvent>,
    pub stragglers: Vec<StragglerWindow>,
    pub link: Option<LinkWindow>,
}

impl EpochFaults {
    /// Multiplier on worker `wid`'s measured compute at `step` (1.0
    /// when no straggler window covers it).
    pub fn compute_multiplier(&self, step: usize, wid: usize) -> f64 {
        for w in &self.stragglers {
            if w.wid == wid && step >= w.start && step < w.end {
                return w.factor;
            }
        }
        1.0
    }

    /// Multiplier on the modeled sync cost at `step`.
    pub fn sync_multiplier(&self, step: usize) -> f64 {
        match &self.link {
            Some(w) if step >= w.start && step < w.end => w.factor,
            _ => 1.0,
        }
    }

    /// The worker that crashes at `step`, if any.
    pub fn crash_at(&self, step: usize) -> Option<usize> {
        match &self.crash {
            Some(c) if c.step == step => Some(c.wid),
            _ => None,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.crash.is_none() && self.stragglers.is_empty() && self.link.is_none()
    }
}

/// Seeded generator of per-epoch fault schedules. Construct once per
/// run from the `[faults]` config; call [`epoch_events`] each epoch.
///
/// [`epoch_events`]: FaultPlan::epoch_events
#[derive(Clone, Debug)]
pub struct FaultPlan {
    cfg: FaultsConfig,
}

impl FaultPlan {
    pub fn new(cfg: &FaultsConfig) -> FaultPlan {
        FaultPlan { cfg: cfg.clone() }
    }

    /// The fault schedule for `epoch` on a `workers` × `steps` grid.
    /// Pure in (seed, epoch, workers, steps) — see the module docs for
    /// the determinism contract and the fixed draw order.
    pub fn epoch_events(&self, epoch: usize, workers: usize, steps: usize) -> EpochFaults {
        let mut out = EpochFaults::default();
        if workers == 0 || steps == 0 {
            return out;
        }
        let seed = self
            .cfg
            .seed
            .wrapping_add((epoch as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let mut rng = Rng::seeded(seed);
        // 1. Stragglers: one Bernoulli + window per worker.
        for wid in 0..workers {
            if rng.next_f64() < self.cfg.straggler_rate {
                let start = rng.below(steps);
                let end = (start + self.cfg.straggler_steps.max(1)).min(steps);
                out.stragglers.push(StragglerWindow {
                    wid,
                    start,
                    end,
                    factor: self.cfg.slowdown_factor,
                });
            }
        }
        // 2. Link degradation: one Bernoulli + window per epoch.
        if rng.next_f64() < self.cfg.link_degrade_rate {
            let start = rng.below(steps);
            let end = (start + self.cfg.link_degrade_steps.max(1)).min(steps);
            out.link = Some(LinkWindow { start, end, factor: self.cfg.link_degrade_factor });
        }
        // 3. Crash: first Bernoulli success in step-major (step, wid)
        //    order. Last in draw order so the early break below cannot
        //    shift the straggler/link draws above.
        'scan: for step in 0..steps {
            for wid in 0..workers {
                if rng.next_f64() < self.cfg.crash_rate {
                    out.crash = Some(CrashEvent { step, wid });
                    break 'scan;
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> FaultsConfig {
        FaultsConfig {
            enabled: true,
            seed: 0xFA17,
            crash_rate: 0.0,
            straggler_rate: 0.0,
            slowdown_factor: 4.0,
            straggler_steps: 8,
            link_degrade_rate: 0.0,
            link_degrade_factor: 4.0,
            link_degrade_steps: 8,
            detect_secs: 1.0,
        }
    }

    #[test]
    fn schedule_is_deterministic_in_seed_and_epoch() {
        let mut c = cfg();
        c.crash_rate = 0.05;
        c.straggler_rate = 0.5;
        c.link_degrade_rate = 0.5;
        let plan = FaultPlan::new(&c);
        for epoch in 0..8 {
            assert_eq!(plan.epoch_events(epoch, 4, 32), plan.epoch_events(epoch, 4, 32));
        }
        // Different epoch => (almost surely) different schedule stream.
        let a: Vec<_> = (0..32).map(|e| plan.epoch_events(e, 4, 32)).collect();
        assert!(a.windows(2).any(|w| w[0] != w[1]), "all epochs drew identical schedules");
    }

    #[test]
    fn zero_rates_produce_empty_schedule() {
        let plan = FaultPlan::new(&cfg());
        for epoch in 0..16 {
            assert!(plan.epoch_events(epoch, 8, 64).is_empty());
        }
        // Degenerate grids are empty too.
        let mut c = cfg();
        c.crash_rate = 1.0;
        let plan = FaultPlan::new(&c);
        assert!(plan.epoch_events(0, 0, 64).is_empty());
        assert!(plan.epoch_events(0, 8, 0).is_empty());
    }

    #[test]
    fn certain_crash_hits_first_grid_cell() {
        let mut c = cfg();
        c.crash_rate = 1.0;
        let plan = FaultPlan::new(&c);
        let ev = plan.epoch_events(3, 4, 32);
        assert_eq!(ev.crash, Some(CrashEvent { step: 0, wid: 0 }));
        assert_eq!(ev.crash_at(0), Some(0));
        assert_eq!(ev.crash_at(1), None);
    }

    #[test]
    fn crash_step_varies_across_epochs() {
        let mut c = cfg();
        c.crash_rate = 0.05;
        let plan = FaultPlan::new(&c);
        let steps: std::collections::BTreeSet<usize> = (0..100)
            .filter_map(|e| plan.epoch_events(e, 4, 32).crash.map(|cr| cr.step))
            .collect();
        assert!(steps.len() >= 2, "crash step never varied: {steps:?}");
    }

    #[test]
    fn straggler_window_bounds_and_multiplier() {
        let mut c = cfg();
        c.straggler_rate = 1.0;
        c.slowdown_factor = 3.0;
        c.straggler_steps = 4;
        let plan = FaultPlan::new(&c);
        let ev = plan.epoch_events(0, 3, 16);
        assert_eq!(ev.stragglers.len(), 3, "every worker straggles at rate 1.0");
        for w in &ev.stragglers {
            assert!(w.start < w.end && w.end <= 16);
            assert!(w.end - w.start <= 4);
            assert_eq!(ev.compute_multiplier(w.start, w.wid), 3.0);
            if w.end < 16 {
                assert_eq!(ev.compute_multiplier(w.end, w.wid), 1.0);
            }
        }
    }

    #[test]
    fn link_window_scales_sync_only_inside() {
        let mut c = cfg();
        c.link_degrade_rate = 1.0;
        c.link_degrade_factor = 2.5;
        c.link_degrade_steps = 4;
        let plan = FaultPlan::new(&c);
        let ev = plan.epoch_events(1, 2, 16);
        let w = ev.link.clone().expect("rate 1.0 must schedule a window");
        assert_eq!(ev.sync_multiplier(w.start), 2.5);
        if w.end < 16 {
            assert_eq!(ev.sync_multiplier(w.end), 1.0);
        }
        if w.start > 0 {
            assert_eq!(ev.sync_multiplier(w.start - 1), 1.0);
        }
    }
}

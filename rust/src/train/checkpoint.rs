//! Checkpointing: flat parameters + Adam state to a small binary format.
//!
//! Layout (little-endian):
//!   magic "KGSC" | version u32 | param_count u64 | adam_t u64
//!   | params f32[n] | adam_m f32[n] | adam_v f32[n]

use anyhow::{Context, Result};
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 4] = b"KGSC";
const VERSION: u32 = 1;

pub struct Checkpoint {
    pub params: Vec<f32>,
    pub adam_m: Vec<f32>,
    pub adam_v: Vec<f32>,
    pub adam_t: u64,
}

pub fn save(path: &Path, params: &[f32], adam_m: &[f32], adam_v: &[f32], adam_t: u64) -> Result<()> {
    anyhow::ensure!(params.len() == adam_m.len() && params.len() == adam_v.len());
    let file = std::fs::File::create(path).with_context(|| format!("creating {path:?}"))?;
    let mut w = std::io::BufWriter::new(file);
    w.write_all(MAGIC)?;
    w.write_all(&VERSION.to_le_bytes())?;
    w.write_all(&(params.len() as u64).to_le_bytes())?;
    w.write_all(&adam_t.to_le_bytes())?;
    for arr in [params, adam_m, adam_v] {
        for &x in arr {
            w.write_all(&x.to_le_bytes())?;
        }
    }
    w.flush()?;
    Ok(())
}

pub fn load(path: &Path) -> Result<Checkpoint> {
    let file = std::fs::File::open(path).with_context(|| format!("opening {path:?}"))?;
    let mut r = std::io::BufReader::new(file);
    let mut magic = [0u8; 4];
    r.read_exact(&mut magic)?;
    anyhow::ensure!(&magic == MAGIC, "not a kgscale checkpoint");
    let mut u32b = [0u8; 4];
    r.read_exact(&mut u32b)?;
    anyhow::ensure!(u32::from_le_bytes(u32b) == VERSION, "unsupported checkpoint version");
    let mut u64b = [0u8; 8];
    r.read_exact(&mut u64b)?;
    let n = u64::from_le_bytes(u64b) as usize;
    r.read_exact(&mut u64b)?;
    let adam_t = u64::from_le_bytes(u64b);
    let mut read_vec = |n: usize| -> Result<Vec<f32>> {
        let mut bytes = vec![0u8; n * 4];
        r.read_exact(&mut bytes)?;
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    };
    let params = read_vec(n)?;
    let adam_m = read_vec(n)?;
    let adam_v = read_vec(n)?;
    Ok(Checkpoint { params, adam_m, adam_v, adam_t })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join(format!("kgscale-ckpt-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("x.ckpt");
        let params = vec![1.0f32, -2.5, 3.25];
        let m = vec![0.1f32, 0.2, 0.3];
        let v = vec![0.01f32, 0.02, 0.03];
        save(&path, &params, &m, &v, 42).unwrap();
        let ck = load(&path).unwrap();
        assert_eq!(ck.params, params);
        assert_eq!(ck.adam_m, m);
        assert_eq!(ck.adam_v, v);
        assert_eq!(ck.adam_t, 42);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn rejects_garbage() {
        let dir = std::env::temp_dir().join(format!("kgscale-ckpt-bad-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.ckpt");
        std::fs::write(&path, b"not a checkpoint").unwrap();
        assert!(load(&path).is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }
}

//! Checkpointing: flat parameters + Adam state to a small binary format.
//!
//! Layout (little-endian):
//!   v1: magic "KGSC" | version u32 | param_count u64 | adam_t u64
//!       | params f32[n] | adam_m f32[n] | adam_v f32[n]
//!   v2: magic "KGSC" | version u32 | grad_mode u32 | param_count u64
//!       | adam_t u64 | params f32[n] | adam_m f32[n] | adam_v f32[n]
//!   v3: magic "KGSC" | version u32 | grad_mode u32 | epoch u64
//!       | param_count u64 | adam_t u64
//!       | params f32[n] | adam_m f32[n] | adam_v f32[n]
//!       | fnv1a64 u64   (checksum over every preceding byte)
//!
//! v2 added the gradient mode so lazy-Adam state is restored under the
//! semantics it was produced with: lazy moments are only valid for
//! rows that were actually touched, so silently resuming a
//! `sparse_lazy` run as `dense` (or vice versa) would change the
//! optimizer trajectory without warning.
//!
//! v3 makes the format crash-consistent. Saves go to `<name>.tmp` in
//! the target directory and are atomically renamed into place (the same
//! pattern as `partition::cache`), so a writer killed mid-save leaves a
//! `.tmp` orphan, never a torn checkpoint. An FNV-1a 64 footer over the
//! whole payload is verified on load, so bit rot or a partially
//! synced file is an error instead of silently-wrong optimizer state.
//! v3 also records the epoch boundary the snapshot was taken at, which
//! `kgscale train --resume` and in-run crash recovery need. Loading
//! still accepts v1 (tagged `dense`, epoch 0) and v2 (epoch 0) files.

use crate::config::GradMode;
use crate::util::hash::Fnv64;
use anyhow::{bail, ensure, Context, Result};
use std::io::{Read, Write};
use std::path::{Path, PathBuf};

const MAGIC: &[u8; 4] = b"KGSC";
const VERSION: u32 = 3;

pub struct Checkpoint {
    pub params: Vec<f32>,
    pub adam_m: Vec<f32>,
    pub adam_v: Vec<f32>,
    pub adam_t: u64,
    /// Gradient mode the optimizer state was produced under.
    pub grad_mode: GradMode,
    /// Epoch boundary this snapshot was taken at: the state equals the
    /// model after `epoch` completed epochs. 0 for v1/v2 files.
    pub epoch: u64,
}

/// Writer that mirrors every byte into the running checksum.
struct HashingWriter<W: Write> {
    inner: W,
    hash: Fnv64,
}

impl<W: Write> HashingWriter<W> {
    fn put(&mut self, bytes: &[u8]) -> Result<()> {
        self.inner.write_all(bytes)?;
        self.hash.write(bytes);
        Ok(())
    }
}

pub fn save(
    path: &Path,
    params: &[f32],
    adam_m: &[f32],
    adam_v: &[f32],
    adam_t: u64,
    grad_mode: GradMode,
    epoch: u64,
) -> Result<()> {
    ensure!(params.len() == adam_m.len() && params.len() == adam_v.len());
    let tmp = tmp_path(path);
    {
        let file = std::fs::File::create(&tmp).with_context(|| format!("creating {tmp:?}"))?;
        let mut w = HashingWriter { inner: std::io::BufWriter::new(file), hash: Fnv64::new() };
        w.put(MAGIC)?;
        w.put(&VERSION.to_le_bytes())?;
        w.put(&grad_mode.as_u32().to_le_bytes())?;
        w.put(&epoch.to_le_bytes())?;
        w.put(&(params.len() as u64).to_le_bytes())?;
        w.put(&adam_t.to_le_bytes())?;
        for arr in [params, adam_m, adam_v] {
            for &x in arr {
                w.put(&x.to_le_bytes())?;
            }
        }
        let checksum = w.hash.finish();
        w.inner.write_all(&checksum.to_le_bytes())?;
        w.inner.flush()?;
    }
    std::fs::rename(&tmp, path).with_context(|| format!("renaming {tmp:?} -> {path:?}"))?;
    Ok(())
}

/// Reader that mirrors every consumed byte into the running checksum.
struct HashingReader<R: Read> {
    inner: R,
    hash: Fnv64,
}

impl<R: Read> HashingReader<R> {
    fn get(&mut self, buf: &mut [u8]) -> Result<()> {
        self.inner.read_exact(buf)?;
        self.hash.write(buf);
        Ok(())
    }

    fn get_u32(&mut self) -> Result<u32> {
        let mut b = [0u8; 4];
        self.get(&mut b)?;
        Ok(u32::from_le_bytes(b))
    }

    fn get_u64(&mut self) -> Result<u64> {
        let mut b = [0u8; 8];
        self.get(&mut b)?;
        Ok(u64::from_le_bytes(b))
    }
}

pub fn load(path: &Path) -> Result<Checkpoint> {
    let file = std::fs::File::open(path).with_context(|| format!("opening {path:?}"))?;
    let file_len = file.metadata().with_context(|| format!("stat {path:?}"))?.len();
    let mut r = HashingReader { inner: std::io::BufReader::new(file), hash: Fnv64::new() };
    let mut magic = [0u8; 4];
    r.get(&mut magic)?;
    ensure!(&magic == MAGIC, "not a kgscale checkpoint");
    let version = r.get_u32()?;
    ensure!(
        (1..=VERSION).contains(&version),
        "unsupported checkpoint version {version}"
    );
    let grad_mode = if version >= 2 {
        GradMode::from_u32(r.get_u32()?)?
    } else {
        GradMode::Dense
    };
    let epoch = if version >= 3 { r.get_u64()? } else { 0 };
    let n64 = r.get_u64()?;
    let adam_t = r.get_u64()?;
    // Bound the claimed param count against the actual file size BEFORE
    // allocating: a corrupt header would otherwise drive `vec![0u8; ..]`
    // straight into an OOM abort instead of an Err.
    let header_len: u64 = match version {
        1 => 24,
        2 => 28,
        _ => 36,
    };
    let footer_len: u64 = if version >= 3 { 8 } else { 0 };
    let body_len = n64
        .checked_mul(12)
        .with_context(|| format!("implausible param count {n64} (overflow)"))?;
    let expected = header_len
        .checked_add(body_len)
        .and_then(|x| x.checked_add(footer_len))
        .with_context(|| format!("implausible param count {n64} (overflow)"))?;
    ensure!(
        expected == file_len,
        "checkpoint {path:?} is truncated or corrupt: \
         header claims {n64} params ({expected} bytes), file holds {file_len}"
    );
    let n = n64 as usize;
    let mut read_vec = |r: &mut HashingReader<_>| -> Result<Vec<f32>> {
        let mut bytes = vec![0u8; n * 4];
        r.get(&mut bytes)?;
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    };
    let params = read_vec(&mut r)?;
    let adam_m = read_vec(&mut r)?;
    let adam_v = read_vec(&mut r)?;
    if version >= 3 {
        let computed = r.hash.finish();
        let mut b = [0u8; 8];
        r.inner.read_exact(&mut b)?;
        let stored = u64::from_le_bytes(b);
        ensure!(
            computed == stored,
            "checkpoint {path:?} checksum mismatch \
             (stored {stored:016x}, computed {computed:016x}): file is corrupt"
        );
    }
    Ok(Checkpoint { params, adam_m, adam_v, adam_t, grad_mode, epoch })
}

/// Path a `save` writes to before the atomic rename into `path`.
fn tmp_path(path: &Path) -> PathBuf {
    let name = path
        .file_name()
        .map(|n| n.to_string_lossy().into_owned())
        .unwrap_or_else(|| "ckpt".to_string());
    path.with_file_name(format!("{name}.tmp"))
}

/// Canonical file name for the snapshot taken at the `epoch` boundary:
/// `<dir>/ckpt-000042.ckpt`. Zero-padding keeps lexical order == epoch
/// order for `ls`-level debugging; `latest` parses the number anyway.
pub fn epoch_file(dir: &Path, epoch: u64) -> PathBuf {
    dir.join(format!("ckpt-{epoch:06}.ckpt"))
}

fn parse_epoch(name: &str) -> Option<u64> {
    name.strip_prefix("ckpt-")?.strip_suffix(".ckpt")?.parse().ok()
}

/// Newest checkpoint in `dir` by epoch tag, if any. A missing directory
/// is `Ok(None)` (nothing saved yet, not an error); `*.tmp` orphans
/// from a crashed save never match the `ckpt-NNNNNN.ckpt` pattern and
/// are ignored.
pub fn latest(dir: &Path) -> Result<Option<(u64, PathBuf)>> {
    let entries = match std::fs::read_dir(dir) {
        Ok(e) => e,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(None),
        Err(e) => return Err(e).with_context(|| format!("reading checkpoint dir {dir:?}")),
    };
    let mut best: Option<(u64, PathBuf)> = None;
    for entry in entries {
        let entry = entry?;
        let name = entry.file_name();
        let Some(tag) = parse_epoch(&name.to_string_lossy()) else { continue };
        let better = match &best {
            Some((b, _)) => tag > *b,
            None => true,
        };
        if better {
            best = Some((tag, entry.path()));
        }
    }
    Ok(best)
}

/// Retention: keep the newest `keep` checkpoints (at least one), delete
/// the rest, and sweep `*.tmp` orphans left by a crashed save. Called
/// after every successful save; a missing directory is a no-op.
pub fn prune(dir: &Path, keep: usize) -> Result<()> {
    let entries = match std::fs::read_dir(dir) {
        Ok(e) => e,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(()),
        Err(e) => return Err(e).with_context(|| format!("reading checkpoint dir {dir:?}")),
    };
    let mut tagged: Vec<(u64, PathBuf)> = Vec::new();
    for entry in entries {
        let entry = entry?;
        let path = entry.path();
        let name = entry.file_name().to_string_lossy().into_owned();
        if name.ends_with(".tmp") {
            std::fs::remove_file(&path)
                .with_context(|| format!("removing tmp orphan {path:?}"))?;
        } else if let Some(tag) = parse_epoch(&name) {
            tagged.push((tag, path));
        }
    }
    tagged.sort_by_key(|(tag, _)| std::cmp::Reverse(*tag));
    for (_, path) in tagged.into_iter().skip(keep.max(1)) {
        std::fs::remove_file(&path).with_context(|| format!("pruning {path:?}"))?;
    }
    Ok(())
}

/// Resume-compatibility check between a checkpoint's gradient mode and
/// the mode a run wants to continue under. Lazy-Adam moments are only
/// valid under lazy semantics, so `sparse_lazy` pairs only with itself;
/// `dense` and `sparse` share bit-identical optimizer state and are
/// interchangeable.
pub fn check_grad_mode(saved: GradMode, running: GradMode) -> Result<()> {
    let saved_lazy = saved == GradMode::SparseLazy;
    let running_lazy = running == GradMode::SparseLazy;
    if saved_lazy != running_lazy {
        bail!(
            "checkpoint grad_mode {} is incompatible with configured grad_mode {}: \
             lazy-Adam state only resumes under sparse_lazy",
            saved.name(),
            running.name()
        );
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("kgscale-ckpt-{tag}-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn sample() -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        (
            vec![1.0f32, -2.5, 3.25],
            vec![0.1f32, 0.2, 0.3],
            vec![0.01f32, 0.02, 0.03],
        )
    }

    #[test]
    fn roundtrip() {
        let dir = tmp_dir("roundtrip");
        let path = dir.join("x.ckpt");
        let (params, m, v) = sample();
        save(&path, &params, &m, &v, 42, GradMode::Dense, 9).unwrap();
        let ck = load(&path).unwrap();
        assert_eq!(ck.params, params);
        assert_eq!(ck.adam_m, m);
        assert_eq!(ck.adam_v, v);
        assert_eq!(ck.adam_t, 42);
        assert_eq!(ck.grad_mode, GradMode::Dense);
        assert_eq!(ck.epoch, 9);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn lazy_adam_state_roundtrips_with_mode_tag() {
        let dir = tmp_dir("lazy");
        let path = dir.join("lazy.ckpt");
        // Lazy moments: zero at never-touched rows, nonzero elsewhere.
        let params = vec![0.5f32, 1.5, -0.25, 2.0];
        let m = vec![0.1f32, 0.0, 0.0, -0.2];
        let v = vec![0.01f32, 0.0, 0.0, 0.04];
        save(&path, &params, &m, &v, 7, GradMode::SparseLazy, 3).unwrap();
        let ck = load(&path).unwrap();
        assert_eq!(ck.grad_mode, GradMode::SparseLazy);
        assert_eq!(ck.adam_m, m);
        assert_eq!(ck.adam_v, v);
        assert_eq!(ck.adam_t, 7);
        assert_eq!(ck.epoch, 3);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn v1_checkpoints_still_load_as_dense() {
        let dir = tmp_dir("v1");
        let path = dir.join("v1.ckpt");
        // Hand-build a v1 file: no grad_mode/epoch fields, no footer.
        let mut bytes: Vec<u8> = Vec::new();
        bytes.extend_from_slice(MAGIC);
        bytes.extend_from_slice(&1u32.to_le_bytes());
        bytes.extend_from_slice(&2u64.to_le_bytes()); // param_count
        bytes.extend_from_slice(&5u64.to_le_bytes()); // adam_t
        for x in [1.0f32, 2.0, 0.1, 0.2, 0.01, 0.02] {
            bytes.extend_from_slice(&x.to_le_bytes());
        }
        std::fs::write(&path, &bytes).unwrap();
        let ck = load(&path).unwrap();
        assert_eq!(ck.grad_mode, GradMode::Dense);
        assert_eq!(ck.params, vec![1.0, 2.0]);
        assert_eq!(ck.adam_t, 5);
        assert_eq!(ck.epoch, 0);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn v2_checkpoints_still_load_without_footer() {
        let dir = tmp_dir("v2");
        let path = dir.join("v2.ckpt");
        // Hand-build a v2 file: grad_mode after version, no epoch/footer.
        let mut bytes: Vec<u8> = Vec::new();
        bytes.extend_from_slice(MAGIC);
        bytes.extend_from_slice(&2u32.to_le_bytes());
        bytes.extend_from_slice(&GradMode::SparseLazy.as_u32().to_le_bytes());
        bytes.extend_from_slice(&2u64.to_le_bytes()); // param_count
        bytes.extend_from_slice(&11u64.to_le_bytes()); // adam_t
        for x in [1.0f32, 2.0, 0.1, 0.2, 0.01, 0.02] {
            bytes.extend_from_slice(&x.to_le_bytes());
        }
        std::fs::write(&path, &bytes).unwrap();
        let ck = load(&path).unwrap();
        assert_eq!(ck.grad_mode, GradMode::SparseLazy);
        assert_eq!(ck.params, vec![1.0, 2.0]);
        assert_eq!(ck.adam_t, 11);
        assert_eq!(ck.epoch, 0);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn rejects_garbage() {
        let dir = tmp_dir("bad");
        let path = dir.join("bad.ckpt");
        std::fs::write(&path, b"not a checkpoint").unwrap();
        assert!(load(&path).is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn save_is_atomic_and_leaves_no_tmp() {
        let dir = tmp_dir("atomic");
        let path = dir.join("a.ckpt");
        let (params, m, v) = sample();
        save(&path, &params, &m, &v, 1, GradMode::Dense, 1).unwrap();
        assert!(path.exists());
        let leftovers: Vec<_> = std::fs::read_dir(&dir)
            .unwrap()
            .map(|e| e.unwrap().file_name().to_string_lossy().into_owned())
            .filter(|n| n.ends_with(".tmp"))
            .collect();
        assert!(leftovers.is_empty(), "tmp files left behind: {leftovers:?}");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn truncated_file_is_rejected() {
        let dir = tmp_dir("trunc");
        let path = dir.join("t.ckpt");
        let (params, m, v) = sample();
        save(&path, &params, &m, &v, 1, GradMode::Dense, 1).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() - 5]).unwrap();
        let err = load(&path).unwrap_err().to_string();
        assert!(err.contains("truncated or corrupt"), "got: {err}");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn flipped_bit_is_caught_by_checksum() {
        let dir = tmp_dir("flip");
        let path = dir.join("f.ckpt");
        let (params, m, v) = sample();
        save(&path, &params, &m, &v, 1, GradMode::Dense, 1).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        // Flip one bit in the params body (header is 36 bytes), which
        // the length check cannot see — only the checksum catches it.
        bytes[40] ^= 0x10;
        std::fs::write(&path, &bytes).unwrap();
        let err = load(&path).unwrap_err().to_string();
        assert!(err.contains("checksum"), "got: {err}");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn garbage_param_count_errors_without_oom() {
        let dir = tmp_dir("oom");
        let path = dir.join("o.ckpt");
        // Header claiming u64::MAX params: `n * 12` overflows.
        let mut bytes: Vec<u8> = Vec::new();
        bytes.extend_from_slice(MAGIC);
        bytes.extend_from_slice(&VERSION.to_le_bytes());
        bytes.extend_from_slice(&GradMode::Dense.as_u32().to_le_bytes());
        bytes.extend_from_slice(&0u64.to_le_bytes()); // epoch
        bytes.extend_from_slice(&u64::MAX.to_le_bytes()); // param_count
        bytes.extend_from_slice(&1u64.to_le_bytes()); // adam_t
        std::fs::write(&path, &bytes).unwrap();
        let err = load(&path).unwrap_err().to_string();
        assert!(err.contains("implausible"), "got: {err}");
        // Header claiming a huge-but-not-overflowing count on a tiny
        // file: bounded by file length, no allocation happens.
        let mut bytes: Vec<u8> = Vec::new();
        bytes.extend_from_slice(MAGIC);
        bytes.extend_from_slice(&VERSION.to_le_bytes());
        bytes.extend_from_slice(&GradMode::Dense.as_u32().to_le_bytes());
        bytes.extend_from_slice(&0u64.to_le_bytes()); // epoch
        bytes.extend_from_slice(&(1u64 << 40).to_le_bytes()); // param_count
        bytes.extend_from_slice(&1u64.to_le_bytes()); // adam_t
        std::fs::write(&path, &bytes).unwrap();
        let err = load(&path).unwrap_err().to_string();
        assert!(err.contains("truncated or corrupt"), "got: {err}");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn latest_ignores_tmp_orphans_and_prune_cleans_them() {
        let dir = tmp_dir("orphan");
        let (params, m, v) = sample();
        save(&epoch_file(&dir, 2), &params, &m, &v, 1, GradMode::Dense, 2).unwrap();
        save(&epoch_file(&dir, 4), &params, &m, &v, 2, GradMode::Dense, 4).unwrap();
        // Simulate a save that crashed mid-write.
        let orphan = dir.join("ckpt-000006.ckpt.tmp");
        std::fs::write(&orphan, b"partial").unwrap();
        let (tag, path) = latest(&dir).unwrap().unwrap();
        assert_eq!(tag, 4);
        assert_eq!(path, epoch_file(&dir, 4));
        prune(&dir, 2).unwrap();
        assert!(!orphan.exists(), "tmp orphan survived prune");
        assert!(epoch_file(&dir, 2).exists());
        assert!(epoch_file(&dir, 4).exists());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn prune_keeps_newest_k() {
        let dir = tmp_dir("prune");
        let (params, m, v) = sample();
        for tag in 1..=5u64 {
            save(&epoch_file(&dir, tag), &params, &m, &v, tag, GradMode::Dense, tag).unwrap();
        }
        prune(&dir, 2).unwrap();
        for tag in 1..=3u64 {
            assert!(!epoch_file(&dir, tag).exists(), "epoch {tag} should be pruned");
        }
        for tag in 4..=5u64 {
            assert!(epoch_file(&dir, tag).exists(), "epoch {tag} should be kept");
        }
        // keep=0 still retains the newest one.
        prune(&dir, 0).unwrap();
        assert!(epoch_file(&dir, 5).exists());
        assert!(!epoch_file(&dir, 4).exists());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn latest_on_missing_dir_is_none() {
        let dir = std::env::temp_dir()
            .join(format!("kgscale-ckpt-missing-{}", std::process::id()));
        assert!(latest(&dir).unwrap().is_none());
        prune(&dir, 3).unwrap(); // also a no-op
    }

    #[test]
    fn grad_mode_compat_matrix() {
        use GradMode::*;
        // dense and sparse share bit-identical optimizer state.
        check_grad_mode(Dense, Dense).unwrap();
        check_grad_mode(Dense, Sparse).unwrap();
        check_grad_mode(Sparse, Dense).unwrap();
        check_grad_mode(SparseLazy, SparseLazy).unwrap();
        let err = check_grad_mode(SparseLazy, Dense).unwrap_err().to_string();
        assert!(err.contains("grad_mode"), "got: {err}");
        assert!(check_grad_mode(Dense, SparseLazy).is_err());
        assert!(check_grad_mode(Sparse, SparseLazy).is_err());
    }
}

//! Checkpointing: flat parameters + Adam state to a small binary format.
//!
//! Layout (little-endian):
//!   v1: magic "KGSC" | version u32 | param_count u64 | adam_t u64
//!       | params f32[n] | adam_m f32[n] | adam_v f32[n]
//!   v2: magic "KGSC" | version u32 | grad_mode u32 | param_count u64
//!       | adam_t u64 | params f32[n] | adam_m f32[n] | adam_v f32[n]
//!
//! v2 adds the gradient mode so lazy-Adam state is restored under the
//! semantics it was produced with: lazy moments are only valid for
//! rows that were actually touched, so silently resuming a
//! `sparse_lazy` run as `dense` (or vice versa) would change the
//! optimizer trajectory without warning. Loading still accepts v1
//! files, which are tagged `dense` (the only mode that existed then).

use crate::config::GradMode;
use anyhow::{Context, Result};
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 4] = b"KGSC";
const VERSION: u32 = 2;

pub struct Checkpoint {
    pub params: Vec<f32>,
    pub adam_m: Vec<f32>,
    pub adam_v: Vec<f32>,
    pub adam_t: u64,
    /// Gradient mode the optimizer state was produced under.
    pub grad_mode: GradMode,
}

pub fn save(
    path: &Path,
    params: &[f32],
    adam_m: &[f32],
    adam_v: &[f32],
    adam_t: u64,
    grad_mode: GradMode,
) -> Result<()> {
    anyhow::ensure!(params.len() == adam_m.len() && params.len() == adam_v.len());
    let file = std::fs::File::create(path).with_context(|| format!("creating {path:?}"))?;
    let mut w = std::io::BufWriter::new(file);
    w.write_all(MAGIC)?;
    w.write_all(&VERSION.to_le_bytes())?;
    w.write_all(&grad_mode.as_u32().to_le_bytes())?;
    w.write_all(&(params.len() as u64).to_le_bytes())?;
    w.write_all(&adam_t.to_le_bytes())?;
    for arr in [params, adam_m, adam_v] {
        for &x in arr {
            w.write_all(&x.to_le_bytes())?;
        }
    }
    w.flush()?;
    Ok(())
}

pub fn load(path: &Path) -> Result<Checkpoint> {
    let file = std::fs::File::open(path).with_context(|| format!("opening {path:?}"))?;
    let mut r = std::io::BufReader::new(file);
    let mut magic = [0u8; 4];
    r.read_exact(&mut magic)?;
    anyhow::ensure!(&magic == MAGIC, "not a kgscale checkpoint");
    let mut u32b = [0u8; 4];
    r.read_exact(&mut u32b)?;
    let version = u32::from_le_bytes(u32b);
    anyhow::ensure!(
        version == 1 || version == VERSION,
        "unsupported checkpoint version {version}"
    );
    let grad_mode = if version >= 2 {
        r.read_exact(&mut u32b)?;
        GradMode::from_u32(u32::from_le_bytes(u32b))?
    } else {
        GradMode::Dense
    };
    let mut u64b = [0u8; 8];
    r.read_exact(&mut u64b)?;
    let n = u64::from_le_bytes(u64b) as usize;
    r.read_exact(&mut u64b)?;
    let adam_t = u64::from_le_bytes(u64b);
    let mut read_vec = |n: usize| -> Result<Vec<f32>> {
        let mut bytes = vec![0u8; n * 4];
        r.read_exact(&mut bytes)?;
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    };
    let params = read_vec(n)?;
    let adam_m = read_vec(n)?;
    let adam_v = read_vec(n)?;
    Ok(Checkpoint { params, adam_m, adam_v, adam_t, grad_mode })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join(format!("kgscale-ckpt-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("x.ckpt");
        let params = vec![1.0f32, -2.5, 3.25];
        let m = vec![0.1f32, 0.2, 0.3];
        let v = vec![0.01f32, 0.02, 0.03];
        save(&path, &params, &m, &v, 42, GradMode::Dense).unwrap();
        let ck = load(&path).unwrap();
        assert_eq!(ck.params, params);
        assert_eq!(ck.adam_m, m);
        assert_eq!(ck.adam_v, v);
        assert_eq!(ck.adam_t, 42);
        assert_eq!(ck.grad_mode, GradMode::Dense);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn lazy_adam_state_roundtrips_with_mode_tag() {
        let dir =
            std::env::temp_dir().join(format!("kgscale-ckpt-lazy-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("lazy.ckpt");
        // Lazy moments: zero at never-touched rows, nonzero elsewhere.
        let params = vec![0.5f32, 1.5, -0.25, 2.0];
        let m = vec![0.1f32, 0.0, 0.0, -0.2];
        let v = vec![0.01f32, 0.0, 0.0, 0.04];
        save(&path, &params, &m, &v, 7, GradMode::SparseLazy).unwrap();
        let ck = load(&path).unwrap();
        assert_eq!(ck.grad_mode, GradMode::SparseLazy);
        assert_eq!(ck.adam_m, m);
        assert_eq!(ck.adam_v, v);
        assert_eq!(ck.adam_t, 7);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn v1_checkpoints_still_load_as_dense() {
        let dir = std::env::temp_dir().join(format!("kgscale-ckpt-v1-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("v1.ckpt");
        // Hand-build a v1 file: no grad_mode field after the version.
        let mut bytes: Vec<u8> = Vec::new();
        bytes.extend_from_slice(MAGIC);
        bytes.extend_from_slice(&1u32.to_le_bytes());
        bytes.extend_from_slice(&2u64.to_le_bytes()); // param_count
        bytes.extend_from_slice(&5u64.to_le_bytes()); // adam_t
        for x in [1.0f32, 2.0, 0.1, 0.2, 0.01, 0.02] {
            bytes.extend_from_slice(&x.to_le_bytes());
        }
        std::fs::write(&path, &bytes).unwrap();
        let ck = load(&path).unwrap();
        assert_eq!(ck.grad_mode, GradMode::Dense);
        assert_eq!(ck.params, vec![1.0, 2.0]);
        assert_eq!(ck.adam_t, 5);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn rejects_garbage() {
        let dir = std::env::temp_dir().join(format!("kgscale-ckpt-bad-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.ckpt");
        std::fs::write(&path, b"not a checkpoint").unwrap();
        assert!(load(&path).is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }
}

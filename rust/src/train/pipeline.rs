//! Pipelined host data path: batch preparation off the coordinator thread.
//!
//! The per-batch host work — compute-graph extraction, bucket selection,
//! and padded-scratch fill — is plain data manipulation with no xla types
//! involved, so it moves off-thread cleanly even though the PJRT
//! [`Runtime`](crate::runtime::Runtime) is not `Send` and stays pinned to
//! the coordinator. This module provides the pieces the trainer composes:
//!
//! - [`HostPool`] (re-exported from [`crate::util::pool`], where it is
//!   shared with the eval pipeline): a persistent `std::thread` pool fed
//!   over an mpsc channel, used here by epoch planning and per-step
//!   batch prep.
//! - [`PadScratch`] + [`prepare_batch`]: one worker batch turned into
//!   execution-ready [`PreparedUnit`]s (usually one; several when the
//!   batch overflows every compiled bucket and is split). **Both** the
//!   sequential and pipelined trainer paths go through [`prepare_batch`],
//!   so their prepared inputs are identical by construction — the
//!   bit-identity contract of `train.host_threads` reduces to executing
//!   the same units in the same `wid` order.
//! - [`worker_epoch_seed`]: the per-(epoch, wid) RNG stream derivation,
//!   shared by both paths so sampling never depends on scheduling.

use crate::model::{EntryInfo, Manifest};
use crate::sampler::compute_graph::{ComputeGraph, ComputeGraphBuilder};
use crate::sampler::{PartContext, TrainTriple};
use crate::util::timer::Stopwatch;
use anyhow::Result;

pub use crate::util::pool::HostPool;

/// Seed for worker `wid`'s RNG stream in `epoch`. Shared by the
/// sequential and pipelined planners so sampled negatives and batch
/// shuffles depend only on `(seed, epoch, wid)` — never on thread
/// scheduling. `| 1` keeps the seed nonzero; the parentheses spell out
/// how the fields pack into disjoint bit ranges (`<<` binds tighter than
/// `^` and `|`, so this is exactly the historical parse).
pub fn worker_epoch_seed(seed: u64, epoch: usize, wid: usize) -> u64 {
    (seed ^ ((epoch as u64) << 20) ^ ((wid as u64) << 8)) | 1
}

/// Reusable padded input buffers (no per-batch allocation on the hot
/// path). Plain `Vec` data, so prepared scratch moves between prep
/// threads and the coordinator freely.
#[derive(Default)]
pub(crate) struct PadScratch {
    pub(crate) node_ids: Vec<i32>,
    pub(crate) node_feat: Vec<f32>,
    pub(crate) src: Vec<i32>,
    pub(crate) dst: Vec<i32>,
    pub(crate) rel: Vec<i32>,
    pub(crate) emask: Vec<f32>,
    pub(crate) ts: Vec<i32>,
    pub(crate) tr: Vec<i32>,
    pub(crate) tt: Vec<i32>,
    pub(crate) labels: Vec<f32>,
    pub(crate) tmask: Vec<f32>,
}

impl PadScratch {
    /// Fill from a compute graph, padding to (n, e, b). `features` is
    /// the dataset's dense feature matrix (empty in embedding mode).
    pub(crate) fn fill(
        &mut self,
        cg: &ComputeGraph,
        features: &[f32],
        feature_dim: usize,
        n: usize,
        e: usize,
        b: usize,
    ) {
        assert!(cg.num_nodes() <= n && cg.num_edges() <= e && cg.num_triples() <= b);
        if feature_dim > 0 {
            let f = feature_dim;
            self.node_feat.clear();
            self.node_feat.resize(n * f, 0.0);
            for (i, &g) in cg.nodes_global.iter().enumerate() {
                let gi = g as usize * f;
                self.node_feat[i * f..(i + 1) * f].copy_from_slice(&features[gi..gi + f]);
            }
        } else {
            self.node_ids.clear();
            self.node_ids.resize(n, 0);
            for (i, &g) in cg.nodes_global.iter().enumerate() {
                self.node_ids[i] = g as i32;
            }
        }
        fill_pad_i32(&mut self.src, &cg.src, e, 0);
        fill_pad_i32(&mut self.dst, &cg.dst, e, 0);
        fill_pad_i32(&mut self.rel, &cg.rel, e, 0);
        fill_pad_f32(&mut self.emask, cg.num_edges(), e);
        fill_pad_i32(&mut self.ts, &cg.ts, b, 0);
        fill_pad_i32(&mut self.tr, &cg.tr, b, 0);
        fill_pad_i32(&mut self.tt, &cg.tt, b, 0);
        self.labels.clear();
        self.labels.extend_from_slice(&cg.labels);
        self.labels.resize(b, 0.0);
        fill_pad_f32(&mut self.tmask, cg.num_triples(), b);
    }
}

fn fill_pad_i32(dst: &mut Vec<i32>, src: &[i32], len: usize, pad: i32) {
    dst.clear();
    dst.extend_from_slice(src);
    dst.resize(len, pad);
}

fn fill_pad_f32(dst: &mut Vec<f32>, ones: usize, len: usize) {
    dst.clear();
    dst.resize(ones, 1.0);
    dst.resize(len, 0.0);
}

/// Plain-data inputs every prep job needs, shared across threads behind
/// an `Arc`.
pub(crate) struct PrepShared {
    pub(crate) manifest: Manifest,
    /// Copy of the dataset's dense features (empty in embedding mode).
    pub(crate) features: Vec<f32>,
    pub(crate) feature_dim: usize,
}

/// Per-worker prep-side state: the arena-backed graph builder plus
/// recycled scratch buffers. Owned by exactly one prep job at a time —
/// handing the state to a job is what serializes a worker's steps.
pub(crate) struct PrepState {
    pub(crate) builder: ComputeGraphBuilder,
    /// Scratch buffers returned after execution, reused by later steps.
    pub(crate) spare: Vec<PadScratch>,
}

/// One execution-ready sub-batch: the compute graph (its touched
/// node/relation sets drive sparse gradient accumulation), the filled
/// scratch, and the chosen `train_step` bucket.
pub(crate) struct PreparedUnit {
    pub(crate) cg: ComputeGraph,
    pub(crate) scratch: PadScratch,
    pub(crate) file: String,
    pub(crate) nodes: usize,
    pub(crate) edges: usize,
    pub(crate) triples: usize,
    pub(crate) batch_len: usize,
}

/// Turn one worker batch into execution-ready units, appended to `units`
/// in order. If the compute graph overflows every compiled bucket the
/// batch is split recursively (sum-losses make this exactly equivalent);
/// the parent's extraction time still counts toward `cg_secs`, matching
/// the sequential path's historical accounting.
pub(crate) fn prepare_batch(
    state: &mut PrepState,
    ctx: &PartContext,
    shared: &PrepShared,
    batch: &[TrainTriple],
    units: &mut Vec<PreparedUnit>,
    cg_secs: &mut f64,
) -> Result<()> {
    let manifest = &shared.manifest;
    let cg_sw = Stopwatch::new();
    let cg = state.builder.build(ctx, batch, manifest.num_layers, manifest.relations);
    *cg_secs += cg_sw.elapsed_secs();

    let bucket = manifest.pick_train_bucket(cg.num_nodes(), cg.num_edges(), cg.num_triples());
    let Some(EntryInfo::TrainStep { file, nodes, edges, triples }) = bucket else {
        anyhow::ensure!(
            batch.len() > 1,
            "compute graph of a single triple (n={}, e={}) exceeds all compiled buckets — \
             re-run `kgscale plan` + `make artifacts`",
            cg.num_nodes(),
            cg.num_edges()
        );
        crate::log_warn!(
            "batch of {} triples overflows buckets (n={} e={}); splitting",
            batch.len(),
            cg.num_nodes(),
            cg.num_edges()
        );
        let mid = batch.len() / 2;
        prepare_batch(state, ctx, shared, &batch[..mid], units, cg_secs)?;
        prepare_batch(state, ctx, shared, &batch[mid..], units, cg_secs)?;
        return Ok(());
    };
    let (file, nodes, edges, triples) = (file.clone(), *nodes, *edges, *triples);
    let mut scratch = state.spare.pop().unwrap_or_default();
    scratch.fill(&cg, &shared.features, shared.feature_dim, nodes, edges, triples);
    units.push(PreparedUnit { cg, scratch, file, nodes, edges, triples, batch_len: batch.len() });
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExperimentConfig;
    use crate::graph::generator;
    use crate::partition;
    use crate::sampler::batch::EpochBatches;
    use crate::sampler::negative::{NegativeSampler, Scope};
    use crate::util::rng::Rng;

    fn assert_send<T: Send>() {}

    #[test]
    fn prep_types_move_off_thread() {
        assert_send::<PadScratch>();
        assert_send::<PrepState>();
        assert_send::<PrepShared>();
        assert_send::<PreparedUnit>();
        assert_send::<PartContext>();
        assert_send::<NegativeSampler>();
        assert_send::<EpochBatches>();
        assert_send::<ComputeGraphBuilder>();
    }

    #[test]
    fn worker_epoch_seeds_are_distinct_and_stable() {
        // Stability: must reproduce the historical unparenthesized
        // expression, which Rust parses with `<<` tightest and `|` last.
        for seed in [0u64, 7, 0x00FF_FF00, u64::MAX] {
            for epoch in 0..4usize {
                for wid in 0..4usize {
                    #[allow(clippy::precedence)]
                    let legacy = seed ^ (epoch as u64) << 20 ^ (wid as u64) << 8 | 1;
                    assert_eq!(worker_epoch_seed(seed, epoch, wid), legacy);
                }
            }
        }
        // Distinct over a realistic (epoch, wid) grid, and the derived
        // streams start differently.
        let mut seen = std::collections::HashSet::new();
        for epoch in 0..64usize {
            for wid in 0..16usize {
                assert!(seen.insert(worker_epoch_seed(7, epoch, wid)));
            }
        }
        let mut a = Rng::seeded(worker_epoch_seed(7, 0, 0));
        let mut b = Rng::seeded(worker_epoch_seed(7, 0, 1));
        assert_ne!(a.next_u64(), b.next_u64());
    }

    fn tiny_context(p: usize) -> (crate::graph::KnowledgeGraph, PartContext) {
        let cfg = ExperimentConfig::tiny();
        let g = generator::generate(&cfg.dataset);
        let mut pcfg = cfg.partition.clone();
        pcfg.num_partitions = p;
        let parts = partition::partition_graph(&g, &pcfg, cfg.dataset.seed);
        let ctx = PartContext::new(&parts[0]);
        (g, ctx)
    }

    /// The bit-identity cornerstone: preparing the same plan through a
    /// fresh state and through a state whose scratch was recycled (as the
    /// pipelined trainer does) yields identical units.
    #[test]
    fn prepare_batch_is_deterministic_across_states() {
        let manifest = Manifest::parse(crate::model::manifest::tests::SAMPLE).unwrap();
        let (g, ctx) = tiny_context(2);
        let sampler = NegativeSampler::new(&ctx, Scope::LocalCore, g.num_entities);
        let mut rng = Rng::seeded(worker_epoch_seed(7, 0, 0));
        let (negs, _) = sampler.sample_epoch(&ctx, 1, &mut rng);
        let plan = EpochBatches::build(&ctx, negs, 32, &mut rng);
        let shared = PrepShared { manifest, features: Vec::new(), feature_dim: 0 };
        let run = |state: &mut PrepState| -> Vec<PreparedUnit> {
            let mut units = Vec::new();
            let mut cg_secs = 0.0;
            for step in 0..plan.num_batches() {
                let batch = plan.batch(step).unwrap();
                prepare_batch(state, &ctx, &shared, batch, &mut units, &mut cg_secs).unwrap();
            }
            units
        };
        let mut fresh = PrepState { builder: ComputeGraphBuilder::new(&ctx), spare: Vec::new() };
        let mut reused = PrepState { builder: ComputeGraphBuilder::new(&ctx), spare: Vec::new() };
        let a = run(&mut fresh);
        reused.spare.extend(run(&mut reused).into_iter().map(|u| u.scratch));
        let b = run(&mut reused);
        assert!(!a.is_empty());
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(b.iter()) {
            assert_eq!(x.file, y.file);
            assert_eq!((x.nodes, x.edges, x.triples), (y.nodes, y.edges, y.triples));
            assert_eq!(x.batch_len, y.batch_len);
            assert_eq!(x.cg.nodes_global, y.cg.nodes_global);
            assert_eq!(x.cg.src, y.cg.src);
            assert_eq!(x.cg.tr, y.cg.tr);
            assert_eq!(x.cg.labels, y.cg.labels);
            assert_eq!(x.scratch.node_ids, y.scratch.node_ids);
            assert_eq!(x.scratch.src, y.scratch.src);
            assert_eq!(x.scratch.labels, y.scratch.labels);
            assert_eq!(x.scratch.tmask, y.scratch.tmask);
        }
    }
}

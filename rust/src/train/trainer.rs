//! The distributed trainer (paper §3.1/§3.3, Algorithm 1), executed on a
//! simulated cluster.
//!
//! Physical layout: XLA execution, gradient accumulation, and the
//! optimizer run on the coordinator thread (the xla wrapper types are
//! not `Send`, so the PJRT [`Runtime`] stays pinned there). The
//! host-side batch work — negative sampling, batch planning,
//! compute-graph extraction, padded-scratch fill — is plain data and
//! runs either inline (`train.host_threads = 0`, the sequential
//! reference path) or on a persistent [`HostPool`]
//! (`train.host_threads > 0`), where prep for steps `s+1..s+depth`
//! proceeds while the coordinator executes step `s`
//! ([`train::pipeline`](crate::train::pipeline)).
//!
//! Logical layout: `P` workers, each bound to one self-sufficient
//! partition, advance in *synchronous steps*. Per step each active
//! worker
//!
//!   1. extracts its edge mini-batch's compute graph (measured, host),
//!   2. executes the AOT `train_step` artifact → (Σ loss, Σ-gradients)
//!      (measured, coordinator),
//!
//! then gradients are combined and one optimizer step is applied. The
//! virtual cluster clock advances by `max_w(compute_w) + T_sync` where
//! `T_sync` comes from the α-β network model (ring AllReduce by default)
//! — i.e. measured compute composed with modeled communication, which is
//! the documented substitution for the paper's 4×2-GPU cluster.
//!
//! **Bit-identity contract:** the pipelined path produces exactly the
//! losses and parameters of the sequential path. Both go through
//! [`prepare_batch`] (identical prepared inputs by construction),
//! per-(epoch, wid) RNG streams are derived by [`worker_epoch_seed`]
//! independent of scheduling, and the coordinator accumulates gradients
//! in fixed `wid` order regardless of prep completion order — verified
//! by the `pipelined_path_bit_identical_to_sequential` e2e test.
//!
//! Mathematical equivalence (§2.2): `train_step` returns the *sum* of
//! per-triple losses and its gradient; the trainer divides the summed
//! gradient by the global triple count. The result is bit-comparable to
//! a single worker processing the union batch — verified by the
//! `distributed_training_parity` and `gradient_modes_*` integration
//! tests. Because averaging makes all replicas identical after every
//! step, the coordinator stores the replica once and hands the same
//! vector to each logical worker.
//!
//! # Gradient modes (`train.grad_mode`)
//!
//! A mini-batch's compute graph touches only the `ent_emb` rows in its
//! `nodes_global` set and the `rel_dec` rows in its triples' relation
//! ids; every other row of either table has an exactly-zero gradient.
//! The gradient path exploits this (DGL-KE, Zheng et al. 2020):
//!
//! - `dense` (default): the reference path. O(param_count) accumulator
//!   zero + add + Adam every step, dense sync bytes.
//! - `sparse`: row-sparse accumulation ([`SparseGrad`]) with *dense*
//!   Adam over the scattered average — **bit-identical** to `dense`
//!   (same losses, same parameters), but the per-step zero/accumulate
//!   cost is O(touched rows) and `grad_sync = "sparse"` may charge sync
//!   on the bytes that actually move.
//! - `sparse_lazy`: row-sparse accumulation + lazy Adam — moments and
//!   parameters update only at touched rows, making the optimizer step
//!   itself O(touched rows). **Not** bit-equivalent to `dense`
//!   (untouched rows skip moment decay; see `train::optimizer` docs);
//!   loss trajectories track the dense path closely.

use crate::config::{ExperimentConfig, GradMode, GradSync};
use crate::graph::KnowledgeGraph;
use crate::metrics::{ComponentTimes, EpochRecord, EvalStats, RunHistory};
use crate::model::{init_params, Manifest};
use crate::partition;
use crate::runtime::{literal_scalar_f32, literal_to_f32_into, HostTensor, Runtime};
use crate::sampler::batch::EpochBatches;
use crate::sampler::compute_graph::ComputeGraphBuilder;
use crate::sampler::negative::{NegativeSampler, Scope};
use crate::sampler::PartContext;
use crate::train::checkpoint;
use crate::train::faults::{EpochFaults, FaultPlan};
use crate::train::netsim::{NetworkModel, VirtualClock};
use crate::train::optimizer::Adam;
use crate::train::pipeline::{
    prepare_batch, worker_epoch_seed, HostPool, PadScratch, PrepShared, PrepState, PreparedUnit,
};
use crate::train::sparse::SparseGrad;
use crate::util::rng::Rng;
use crate::util::timer::Stopwatch;
use anyhow::{Context, Result};
use std::collections::VecDeque;
use std::path::{Path, PathBuf};
use std::sync::mpsc::{self, Receiver, Sender};
use std::sync::Arc;
use std::time::Duration;

/// One logical trainer process bound to a partition. `ctx` and `sampler`
/// are shared with prep jobs via `Arc`; `prep` (builder + recycled
/// scratch) is owned by exactly one prep job at a time — `None` while a
/// job is in flight on the pool.
struct Worker {
    ctx: Arc<PartContext>,
    sampler: Arc<NegativeSampler>,
    prep: Option<PrepState>,
}

/// Where a worker batch's gradient readback is accumulated: the dense
/// reference accumulator, or the row-sparse one keyed off the compute
/// graph's touched node/relation sets.
enum GradSink<'a> {
    Dense(&'a mut Vec<f32>),
    Sparse(&'a mut SparseGrad),
}

/// What a prep job sends back to the coordinator. The worker's
/// `PrepState` rides along so it is restored (and the next job can be
/// submitted) even when preparation failed.
struct PrepResult {
    wid: usize,
    state: PrepState,
    units: Result<(Vec<PreparedUnit>, f64)>,
    /// Seconds the job occupied a pool thread (overlap accounting).
    prep_secs: f64,
}

/// Per-epoch scalar accumulators threaded through both step paths.
#[derive(Default)]
struct EpochStats {
    loss_sum: f64,
    count_sum: f64,
    touched_sum: f64,
    sync_bytes_sum: f64,
    /// Coordinator seconds blocked waiting on a prep result.
    stall_secs: f64,
    /// Total seconds prep jobs kept pool threads busy.
    prep_busy_secs: f64,
    /// Crash-recovery events this epoch (`train::faults`).
    crashes: usize,
    /// Steps deterministically re-executed by those recoveries.
    replayed_steps: usize,
    /// Virtual seconds charged for detection + restore + replay.
    recovery_secs: f64,
    /// Extra virtual compute injected by straggler windows.
    straggler_secs: f64,
}

/// Periodic-checkpoint bookkeeping: where snapshots go, how often, and
/// how much work a crash would have to replay from the newest one.
struct CkptState {
    dir: PathBuf,
    /// Snapshot cadence in epochs (`train.checkpoint_every_epochs` > 0).
    every: usize,
    /// Retention (`train.checkpoint_keep`).
    keep: usize,
    /// Epoch tag of the newest on-disk snapshot, once one exists.
    last_epoch: Option<u64>,
    /// Virtual seconds of completed epochs since that snapshot (what a
    /// recovery would replay, beyond the crashed epoch's own progress).
    virtual_since: f64,
    /// Synchronous steps of completed epochs since that snapshot.
    steps_since: usize,
}

pub struct Trainer<'rt> {
    pub cfg: ExperimentConfig,
    pub manifest: Manifest,
    runtime: &'rt Runtime,
    workers: Vec<Worker>,
    pub params: Vec<f32>,
    opt: Adam,
    net: NetworkModel,
    /// Dense gradient accumulator (`dense` mode) / all-zero scatter
    /// target (`sparse` mode). Empty in `sparse_lazy` mode, which never
    /// materializes a dense gradient.
    grads_accum: Vec<f32>,
    /// Row-sparse accumulator for the `sparse` / `sparse_lazy` modes.
    sparse_accum: Option<SparseGrad>,
    grad_scratch: Vec<f32>,
    /// Plain-data inputs shared with prep jobs (manifest copy + the
    /// dataset's dense feature matrix, empty in embedding mode).
    shared: Arc<PrepShared>,
    /// Host prep pool; `None` ⇒ sequential reference path.
    pool: Option<HostPool>,
    /// Seeded fault schedule; `None` ⇔ `faults.enabled = false`, which
    /// keeps every step on the exact pre-fault-layer code path.
    faults: Option<FaultPlan>,
    /// Periodic-checkpoint state; `None` ⇔ checkpointing off.
    ckpt: Option<CkptState>,
    pub history: RunHistory,
    epoch_counter: usize,
}

impl<'rt> Trainer<'rt> {
    /// Partition the graph per the config and set up `num_trainers`
    /// logical workers.
    pub fn new(
        cfg: ExperimentConfig,
        graph: &KnowledgeGraph,
        runtime: &'rt Runtime,
        manifest: Manifest,
    ) -> Result<Self> {
        anyhow::ensure!(
            manifest.entities >= graph.num_entities,
            "manifest compiled for {} entities but dataset has {}",
            manifest.entities,
            graph.num_entities
        );
        let mut pcfg = cfg.partition.clone();
        pcfg.num_partitions = cfg.train.num_trainers;
        let (parts, build) = partition::build_partitions(graph, &pcfg, cfg.dataset.seed);
        crate::log_info!("{}", build.summary());
        let scope = if cfg.train.local_negatives { Scope::LocalCore } else { Scope::Global };
        let workers = parts
            .iter()
            .map(|p| {
                let ctx = Arc::new(PartContext::new(p));
                let sampler = Arc::new(NegativeSampler::new(&ctx, scope, graph.num_entities));
                let builder = ComputeGraphBuilder::new(&ctx);
                Worker { ctx, sampler, prep: Some(PrepState { builder, spare: Vec::new() }) }
            })
            .collect();
        if manifest.mode == "provided" {
            anyhow::ensure!(
                graph.feature_dim == manifest.feature_dim,
                "dataset feature_dim {} != manifest feature_dim {}",
                graph.feature_dim,
                manifest.feature_dim
            );
        }
        let params = init_params(&manifest, cfg.train.seed);
        let opt = Adam::from_config(manifest.param_count, &cfg.train);
        let net = NetworkModel::new(&cfg.network);
        // `sparse_lazy` never materializes a dense gradient, so skip the
        // param_count-sized allocation entirely.
        let grads_accum = match cfg.train.grad_mode {
            GradMode::SparseLazy => Vec::new(),
            _ => vec![0f32; manifest.param_count],
        };
        let sparse_accum = match cfg.train.grad_mode {
            GradMode::Dense => None,
            _ => {
                let ent = manifest.embedding_segment();
                if ent.is_none() {
                    crate::log_warn!(
                        "grad_mode {} without an ent_emb table (provided-features \
                         mode): the whole vector is treated as the dense tail",
                        cfg.train.grad_mode.name()
                    );
                }
                let rel = manifest
                    .relation_segment()
                    .filter(|r| r.offset >= ent.map_or(0, |e| e.end()));
                Some(SparseGrad::with_relations(ent, rel, manifest.param_count))
            }
        };
        let grad_scratch = Vec::with_capacity(manifest.param_count);
        let (features, feature_dim) = if manifest.mode == "provided" {
            (graph.features.clone(), graph.feature_dim)
        } else {
            (Vec::new(), 0)
        };
        let shared = Arc::new(PrepShared { manifest: manifest.clone(), features, feature_dim });
        let pool = (cfg.train.host_threads > 0).then(|| HostPool::new(cfg.train.host_threads));
        // Pre-compile every train_step bucket so epoch timings measure
        // steady-state execution, not one-off PJRT compilation.
        for e in &manifest.entries {
            if let crate::model::EntryInfo::TrainStep { file, .. } = e {
                runtime.load(file)?;
            }
        }
        let faults = cfg.faults.enabled.then(|| FaultPlan::new(&cfg.faults));
        let ckpt = (cfg.train.checkpoint_every_epochs > 0).then(|| CkptState {
            dir: PathBuf::from(&cfg.train.checkpoint_dir),
            every: cfg.train.checkpoint_every_epochs,
            keep: cfg.train.checkpoint_keep,
            last_epoch: None,
            virtual_since: 0.0,
            steps_since: 0,
        });
        Ok(Trainer {
            cfg,
            manifest,
            runtime,
            workers,
            params,
            opt,
            net,
            grads_accum,
            sparse_accum,
            grad_scratch,
            shared,
            pool,
            faults,
            ckpt,
            history: RunHistory::default(),
            epoch_counter: 0,
        })
    }

    pub fn num_workers(&self) -> usize {
        self.workers.len()
    }

    /// Core-edge counts per worker (workload-balance diagnostics).
    pub fn worker_core_edges(&self) -> Vec<usize> {
        self.workers.iter().map(|w| w.ctx.core_edges.len()).collect()
    }

    /// Phase 1 (per paper Algorithm 1 line 3): every worker samples its
    /// epoch negatives and builds its shuffled batch plan. With a host
    /// pool the P workers plan in parallel; the per-(epoch, wid) RNG
    /// streams make the resulting plans identical either way.
    fn plan_epoch(&self, epoch: usize) -> Result<(Vec<Arc<EpochBatches>>, usize)> {
        let p = self.workers.len();
        let seed = self.cfg.train.seed;
        let per_pos = self.cfg.train.negatives_per_positive;
        let batch_edges = self.cfg.train.batch_edges;
        if let Some(pool) = &self.pool {
            let (tx, rx) = mpsc::channel();
            for (wid, w) in self.workers.iter().enumerate() {
                let ctx = Arc::clone(&w.ctx);
                let sampler = Arc::clone(&w.sampler);
                let tx = tx.clone();
                pool.submit(move || {
                    let mut rng = Rng::seeded(worker_epoch_seed(seed, epoch, wid));
                    let (negs, remote) = sampler.sample_epoch(&ctx, per_pos, &mut rng);
                    let ep = EpochBatches::build(&ctx, negs, batch_edges, &mut rng);
                    let _ = tx.send((wid, ep, remote));
                });
            }
            drop(tx);
            let mut slots: Vec<Option<Arc<EpochBatches>>> = (0..p).map(|_| None).collect();
            let mut total_remote = 0usize;
            for _ in 0..p {
                let (wid, ep, remote) =
                    rx.recv().map_err(|_| anyhow::anyhow!("epoch-plan worker died"))?;
                slots[wid] = Some(Arc::new(ep));
                total_remote += remote;
            }
            let plans = slots.into_iter().map(|s| s.expect("one plan per worker")).collect();
            Ok((plans, total_remote))
        } else {
            let mut plans = Vec::with_capacity(p);
            let mut total_remote = 0usize;
            for (wid, w) in self.workers.iter().enumerate() {
                let mut rng = Rng::seeded(worker_epoch_seed(seed, epoch, wid));
                let (negs, remote) = w.sampler.sample_epoch(&w.ctx, per_pos, &mut rng);
                total_remote += remote;
                plans.push(Arc::new(EpochBatches::build(&w.ctx, negs, batch_edges, &mut rng)));
            }
            Ok((plans, total_remote))
        }
    }

    /// Run one epoch of synchronous distributed training; returns the
    /// epoch record (also appended to `history`).
    pub fn train_epoch(&mut self) -> Result<EpochRecord> {
        let epoch = self.epoch_counter;
        self.epoch_counter += 1;
        let wall = Stopwatch::new();
        let mut clk = VirtualClock::new();
        let mut components = ComponentTimes::new();
        let mut ckpt_write_secs = 0.0;
        // With checkpointing on, snapshot the pre-training state before
        // the first epoch runs so a crash in it has something to restore.
        if self.ckpt.as_ref().is_some_and(|c| c.last_epoch.is_none()) {
            ckpt_write_secs += self.write_checkpoint_tag(epoch as u64)?;
        }

        let (plans, total_remote) = self.plan_epoch(epoch)?;
        // Remote fetches (global-negative ablation) are charged to the
        // virtual clock: one embedding row per fetch.
        if total_remote > 0 {
            let bytes = self.manifest.embed_dim * 4;
            clk.advance(total_remote as f64 * self.net.fetch_secs(bytes));
        }

        let steps = plans.iter().map(|b| b.num_batches()).max().unwrap_or(0);
        // Materialize this epoch's fault schedule up front (owned, so the
        // step loops can borrow `self` mutably). `None` with faults off.
        let faults = self
            .faults
            .as_ref()
            .map(|p| p.epoch_events(epoch, self.workers.len(), steps));
        let mut stats = EpochStats::default();
        if self.pool.is_some() {
            self.steps_pipelined(
                epoch,
                &plans,
                steps,
                faults.as_ref(),
                &mut clk,
                &mut components,
                &mut stats,
            )?;
        } else {
            self.steps_sequential(
                epoch,
                &plans,
                steps,
                faults.as_ref(),
                &mut clk,
                &mut components,
                &mut stats,
            )?;
        }

        // Account this epoch toward what a future crash would replay,
        // then snapshot at the configured epoch-boundary cadence (which
        // resets that account).
        if let Some(ck) = &mut self.ckpt {
            ck.virtual_since += clk.now();
            ck.steps_since += steps;
        }
        if self.ckpt.as_ref().is_some_and(|c| (epoch + 1) % c.every == 0) {
            ckpt_write_secs += self.write_checkpoint_tag(epoch as u64 + 1)?;
        }
        // Checkpoint writes are coordinator-serial work on the virtual
        // cluster too.
        clk.advance(ckpt_write_secs);

        // Overlap efficiency: the share of host prep work hidden behind
        // coordinator execution. 0.0 on the sequential path (no
        // concurrent prep to hide).
        let overlap = if stats.prep_busy_secs > 0.0 {
            ((stats.prep_busy_secs - stats.stall_secs) / stats.prep_busy_secs).clamp(0.0, 1.0)
        } else {
            0.0
        };
        let record = EpochRecord {
            epoch,
            mean_loss: if stats.count_sum > 0.0 {
                stats.loss_sum / stats.count_sum
            } else {
                f64::NAN
            },
            virtual_secs: clk.now(),
            wall_secs: wall.elapsed_secs(),
            num_steps: steps,
            avg_compute_graph: components.get_compute_graph.mean(),
            avg_gnn_model: components.gnn_model.mean(),
            avg_sync_step: components.sync_step.mean(),
            remote_fetches: total_remote,
            avg_touched_rows: if steps > 0 { stats.touched_sum / steps as f64 } else { 0.0 },
            avg_sync_bytes: if steps > 0 { stats.sync_bytes_sum / steps as f64 } else { 0.0 },
            prefetch_stall_secs: stats.stall_secs,
            overlap_efficiency: overlap,
            eval_wall_secs: 0.0,
            eval_rank_stall_secs: 0.0,
            eval_overlap_efficiency: 0.0,
            fault_recoveries: stats.crashes,
            replayed_steps: stats.replayed_steps,
            recovery_secs: stats.recovery_secs,
            straggler_secs: stats.straggler_secs,
            checkpoint_write_secs: ckpt_write_secs,
        };
        self.history.epochs.push(record.clone());
        Ok(record)
    }

    /// Sequential reference path: prepare and execute each worker's
    /// batch inline, in `wid` order.
    #[allow(clippy::too_many_arguments)]
    fn steps_sequential(
        &mut self,
        epoch: usize,
        plans: &[Arc<EpochBatches>],
        steps: usize,
        faults: Option<&EpochFaults>,
        clk: &mut VirtualClock,
        components: &mut ComponentTimes,
        stats: &mut EpochStats,
    ) -> Result<()> {
        let p = self.workers.len();
        let mut units: Vec<PreparedUnit> = Vec::new();
        for step in 0..steps {
            self.reset_step_accumulator();
            let mut step_compute: Vec<f64> = Vec::with_capacity(p);
            let mut step_loss = 0f64;
            let mut step_count = 0f64;
            for wid in 0..p {
                let Some(batch) = plans[wid].batch(step) else { continue };
                let mut cg_secs = 0f64;
                {
                    let w = &mut self.workers[wid];
                    let state = w.prep.as_mut().expect("prep state resident when sequential");
                    prepare_batch(state, &w.ctx, &self.shared, batch, &mut units, &mut cg_secs)?;
                }
                let (loss, count, exec_secs) = self.execute_worker_units(&units, epoch)?;
                let state = self.workers[wid].prep.as_mut().expect("prep state resident");
                for u in units.drain(..) {
                    state.spare.push(u.scratch);
                }
                step_loss += loss;
                step_count += count;
                components.get_compute_graph.push(cg_secs);
                components.gnn_model.push(exec_secs);
                // Straggler windows inflate this worker's virtual
                // compute; component means keep the raw measurement.
                let mut compute = cg_secs + exec_secs;
                if let Some(f) = faults {
                    let m = f.compute_multiplier(step, wid);
                    if m > 1.0 {
                        stats.straggler_secs += compute * (m - 1.0);
                        compute *= m;
                    }
                }
                step_compute.push(compute);
            }
            components.prefetch_stall.push(0.0);
            stats.loss_sum += step_loss;
            stats.count_sum += step_count;
            self.sync_and_step(
                epoch,
                step,
                faults,
                &step_compute,
                step_count,
                clk,
                components,
                stats,
            )?;
        }
        Ok(())
    }

    /// Pipelined path: prep jobs for up to `prefetch_depth` steps ahead
    /// run on the host pool while the coordinator executes the current
    /// step. Per-worker results arrive in step order (a worker's
    /// `PrepState` is owned by one job at a time, serializing its
    /// steps), and the coordinator consumes them in fixed `wid` order —
    /// so accumulation order matches the sequential path exactly.
    #[allow(clippy::too_many_arguments)]
    fn steps_pipelined(
        &mut self,
        epoch: usize,
        plans: &[Arc<EpochBatches>],
        steps: usize,
        faults: Option<&EpochFaults>,
        clk: &mut VirtualClock,
        components: &mut ComponentTimes,
        stats: &mut EpochStats,
    ) -> Result<()> {
        let p = self.workers.len();
        let (tx, rx) = mpsc::channel::<PrepResult>();
        let mut next_prep = vec![0usize; p];
        let mut pending_scratch: Vec<Vec<_>> = (0..p).map(|_| Vec::new()).collect();
        let mut ready: Vec<VecDeque<(Vec<PreparedUnit>, f64)>> =
            (0..p).map(|_| VecDeque::new()).collect();
        let mut in_flight = 0usize;

        let result = self.pipelined_loop(
            epoch,
            plans,
            steps,
            faults,
            clk,
            components,
            stats,
            &tx,
            &rx,
            &mut next_prep,
            &mut pending_scratch,
            &mut in_flight,
            &mut ready,
        );
        // Success leaves nothing in flight; on error, bring every
        // outstanding prep state home so the trainer stays usable.
        while in_flight > 0 {
            match rx.recv_timeout(Duration::from_secs(60)) {
                Ok(r) => {
                    in_flight -= 1;
                    self.workers[r.wid].prep = Some(r.state);
                }
                Err(_) => break,
            }
        }
        result
    }

    #[allow(clippy::too_many_arguments)]
    fn pipelined_loop(
        &mut self,
        epoch: usize,
        plans: &[Arc<EpochBatches>],
        steps: usize,
        faults: Option<&EpochFaults>,
        clk: &mut VirtualClock,
        components: &mut ComponentTimes,
        stats: &mut EpochStats,
        tx: &Sender<PrepResult>,
        rx: &Receiver<PrepResult>,
        next_prep: &mut [usize],
        pending_scratch: &mut [Vec<PadScratch>],
        in_flight: &mut usize,
        ready: &mut [VecDeque<(Vec<PreparedUnit>, f64)>],
    ) -> Result<()> {
        let p = self.workers.len();
        let depth = self.cfg.train.prefetch_depth;
        for step in 0..steps {
            self.submit_prep_jobs(plans, tx, next_prep, pending_scratch, in_flight, step, depth);
            self.reset_step_accumulator();
            let mut step_compute: Vec<f64> = Vec::with_capacity(p);
            let mut step_loss = 0f64;
            let mut step_count = 0f64;
            let mut step_stall = 0f64;
            for wid in 0..p {
                if step >= plans[wid].num_batches() {
                    continue;
                }
                while ready[wid].is_empty() {
                    let stall_sw = Stopwatch::new();
                    let r = rx.recv().map_err(|_| anyhow::anyhow!("prep result channel closed"))?;
                    step_stall += stall_sw.elapsed_secs();
                    *in_flight -= 1;
                    stats.prep_busy_secs += r.prep_secs;
                    self.workers[r.wid].prep = Some(r.state);
                    let (units, cg_secs) = r.units?;
                    ready[r.wid].push_back((units, cg_secs));
                    self.submit_prep_jobs(
                        plans,
                        tx,
                        next_prep,
                        pending_scratch,
                        in_flight,
                        step,
                        depth,
                    );
                }
                // Per-wid results arrive in step order, so the front of
                // the queue is exactly this step's prepared batch.
                let (units, cg_secs) = ready[wid].pop_front().expect("nonempty after wait");
                let (loss, count, exec_secs) = self.execute_worker_units(&units, epoch)?;
                pending_scratch[wid].extend(units.into_iter().map(|u| u.scratch));
                step_loss += loss;
                step_count += count;
                components.get_compute_graph.push(cg_secs);
                components.gnn_model.push(exec_secs);
                // Straggler windows inflate this worker's virtual
                // compute; component means keep the raw measurement.
                let mut compute = cg_secs + exec_secs;
                if let Some(f) = faults {
                    let m = f.compute_multiplier(step, wid);
                    if m > 1.0 {
                        stats.straggler_secs += compute * (m - 1.0);
                        compute *= m;
                    }
                }
                step_compute.push(compute);
            }
            components.prefetch_stall.push(step_stall);
            stats.stall_secs += step_stall;
            stats.loss_sum += step_loss;
            stats.count_sum += step_count;
            self.sync_and_step(
                epoch,
                step,
                faults,
                &step_compute,
                step_count,
                clk,
                components,
                stats,
            )?;
        }
        Ok(())
    }

    /// Submit one prep job per worker whose state is resident, next
    /// batch exists, and whose prep is at most `depth` steps ahead of
    /// execution. At most one job per worker is ever in flight (the
    /// job owns the worker's `PrepState`), which both serializes a
    /// worker's steps and bounds buffered scratch.
    #[allow(clippy::too_many_arguments)]
    fn submit_prep_jobs(
        &mut self,
        plans: &[Arc<EpochBatches>],
        tx: &Sender<PrepResult>,
        next_prep: &mut [usize],
        pending_scratch: &mut [Vec<PadScratch>],
        in_flight: &mut usize,
        exec_step: usize,
        depth: usize,
    ) {
        let pool = self.pool.as_ref().expect("pipelined path has a pool");
        for wid in 0..self.workers.len() {
            let s = next_prep[wid];
            if s >= plans[wid].num_batches() || s > exec_step + depth {
                continue;
            }
            let Some(mut state) = self.workers[wid].prep.take() else { continue };
            // Recycle scratch returned by executed units before the
            // state leaves the coordinator.
            state.spare.append(&mut pending_scratch[wid]);
            let ctx = Arc::clone(&self.workers[wid].ctx);
            let shared = Arc::clone(&self.shared);
            let plan = Arc::clone(&plans[wid]);
            let tx = tx.clone();
            pool.submit(move || {
                let sw = Stopwatch::new();
                let mut units = Vec::new();
                let mut cg_secs = 0f64;
                let res = match plan.batch(s) {
                    Some(batch) => {
                        prepare_batch(&mut state, &ctx, &shared, batch, &mut units, &mut cg_secs)
                            .map(|()| (units, cg_secs))
                    }
                    None => Err(anyhow::anyhow!("prep step {s} out of plan range")),
                };
                let prep_secs = sw.elapsed_secs();
                let _ = tx.send(PrepResult { wid, state, units: res, prep_secs });
            });
            next_prep[wid] = s + 1;
            *in_flight += 1;
        }
    }

    /// Reset the step accumulator: O(param_count) only in dense mode;
    /// the sparse modes clear just the previously-touched rows + the
    /// small dense remainder.
    fn reset_step_accumulator(&mut self) {
        match self.cfg.train.grad_mode {
            GradMode::Dense => self.grads_accum.fill(0.0),
            _ => self.sparse_accum.as_mut().expect("sparse accumulator").clear(),
        }
    }

    /// Execute one worker's prepared units on the coordinator,
    /// accumulating gradients into the configured sink. Returns
    /// (Σ loss, triple count, exec seconds).
    fn execute_worker_units(
        &mut self,
        units: &[PreparedUnit],
        epoch: usize,
    ) -> Result<(f64, f64, f64)> {
        let mut sink = match self.cfg.train.grad_mode {
            GradMode::Dense => GradSink::Dense(&mut self.grads_accum),
            _ => GradSink::Sparse(self.sparse_accum.as_mut().expect("sparse accumulator")),
        };
        execute_units(
            units,
            &self.manifest,
            self.runtime,
            &self.params,
            &mut sink,
            &mut self.grad_scratch,
            self.cfg.train.seed,
            epoch,
        )
    }

    /// Gradient averaging: modeled sync + measured optimizer step, then
    /// advance the virtual clock. Sparse sync is charged on the bytes
    /// that actually move — the union touched entity/relation rows +
    /// dense remainder — instead of the full `param_count * 4`. With a
    /// fault schedule, the sync cost is inflated inside link-degradation
    /// windows and a scheduled crash at this step triggers recovery at
    /// the barrier.
    #[allow(clippy::too_many_arguments)]
    fn sync_and_step(
        &mut self,
        epoch: usize,
        step: usize,
        faults: Option<&EpochFaults>,
        step_compute: &[f64],
        step_count: f64,
        clk: &mut VirtualClock,
        components: &mut ComponentTimes,
        stats: &mut EpochStats,
    ) -> Result<()> {
        let p = self.workers.len();
        let (sync_bytes, touched) = match &self.sparse_accum {
            Some(sg) if self.cfg.train.grad_sync == GradSync::Sparse => {
                (sg.transfer_bytes(), sg.touched_rows())
            }
            Some(sg) => (self.manifest.param_count * 4, sg.touched_rows()),
            None => (self.manifest.param_count * 4, 0),
        };
        stats.touched_sum += touched as f64;
        stats.sync_bytes_sum += sync_bytes as f64;
        let sync_model_secs = match faults {
            Some(f) => self.net.sync_secs_degraded(
                self.cfg.train.grad_sync,
                sync_bytes,
                p,
                f.sync_multiplier(step),
            ),
            None => self.net.sync_secs(self.cfg.train.grad_sync, sync_bytes, p),
        };
        let opt_sw = Stopwatch::new();
        if step_count > 0.0 {
            let inv = (1.0 / step_count) as f32;
            match self.cfg.train.grad_mode {
                GradMode::Dense => {
                    for g in self.grads_accum.iter_mut() {
                        *g *= inv;
                    }
                    self.opt.step(&mut self.params, &self.grads_accum);
                }
                GradMode::Sparse => {
                    // Scatter into the persistent all-zero dense vector
                    // and run the reference Adam: bit-identical to dense
                    // mode, O(touched) scatter + unscatter.
                    let sg = self.sparse_accum.as_mut().expect("sparse accumulator");
                    sg.scale(inv);
                    sg.scatter_into(&mut self.grads_accum);
                    self.opt.step(&mut self.params, &self.grads_accum);
                    sg.clear_scatter(&mut self.grads_accum);
                }
                GradMode::SparseLazy => {
                    let sg = self.sparse_accum.as_mut().expect("sparse accumulator");
                    sg.scale(inv);
                    self.opt.step_lazy(&mut self.params, sg);
                }
            }
        }
        let opt_secs = opt_sw.elapsed_secs();
        components.sync_step.push(sync_model_secs + opt_secs);
        clk.step(step_compute, sync_model_secs + opt_secs);
        // A scheduled crash surfaces at this step's barrier: the missing
        // replica is detected and recovery runs before the next step.
        if let Some(wid) = faults.and_then(|f| f.crash_at(step)) {
            self.recover_from_crash(epoch, step, wid, clk, stats)?;
        }
        Ok(())
    }

    /// Crash recovery at the synchronous barrier. The dead worker `wid`
    /// is replaced: the last checkpoint is read back in full (which
    /// also exercises the checksum path), shipped over the modeled
    /// interconnect, and the steps since that snapshot are replayed.
    /// The live replica is *not* overwritten — training is
    /// deterministic in (seed, epoch, wid), so replaying from the
    /// snapshot reconstructs exactly the state the survivors already
    /// hold; only the cost of detection + restore + transfer + replay
    /// is charged to the virtual clock. This is what makes the
    /// recovered-run-matches-fault-free-run invariant hold bit-for-bit.
    fn recover_from_crash(
        &mut self,
        epoch: usize,
        step: usize,
        wid: usize,
        clk: &mut VirtualClock,
        stats: &mut EpochStats,
    ) -> Result<()> {
        let (dir, last, virtual_since, steps_since) = match &self.ckpt {
            Some(ck) => (ck.dir.clone(), ck.last_epoch, ck.virtual_since, ck.steps_since),
            None => anyhow::bail!(
                "worker {wid} crashed at epoch {epoch} step {step} but checkpointing \
                 is disabled (train.checkpoint_every_epochs = 0)"
            ),
        };
        let tag = last.with_context(|| {
            format!("worker {wid} crashed before the first checkpoint was written")
        })?;
        let path = checkpoint::epoch_file(&dir, tag);
        let read_sw = Stopwatch::new();
        let restored = checkpoint::load(&path)
            .with_context(|| format!("restoring after crash of worker {wid}"))?;
        let read_secs = read_sw.elapsed_secs();
        anyhow::ensure!(
            restored.params.len() == self.manifest.param_count,
            "checkpoint {path:?} has {} params but manifest expects {}",
            restored.params.len(),
            self.manifest.param_count
        );
        // Restored state ships to the replacement replica over the
        // cross-node link: v3 header+footer (44 bytes) + 3 f32 arrays.
        let transfer_bytes = 44 + restored.params.len() * 12;
        let transfer_secs = self.net.fetch_secs(transfer_bytes);
        // Deterministic replay re-executes everything since the
        // snapshot: the completed epochs' virtual time plus this
        // epoch's progress up to and including the crash step.
        let replay_secs = virtual_since + clk.now();
        let replayed_steps = steps_since + step + 1;
        let recovery_secs =
            self.cfg.faults.detect_secs + read_secs + transfer_secs + replay_secs;
        clk.advance(recovery_secs);
        stats.crashes += 1;
        stats.replayed_steps += replayed_steps;
        stats.recovery_secs += recovery_secs;
        crate::log_info!(
            "worker {wid} crashed at epoch {epoch} step {step}: restored ckpt-{tag:06}, \
             replayed {replayed_steps} steps, charged {recovery_secs:.3} virtual secs"
        );
        Ok(())
    }

    /// Write the periodic snapshot tagged `tag` (completed epochs),
    /// prune to the retention window, and reset the replay account.
    /// Returns the wall seconds the write took; no-op (0.0) when
    /// checkpointing is off.
    fn write_checkpoint_tag(&mut self, tag: u64) -> Result<f64> {
        let (dir, keep) = match &self.ckpt {
            Some(ck) => (ck.dir.clone(), ck.keep),
            None => return Ok(0.0),
        };
        std::fs::create_dir_all(&dir)
            .with_context(|| format!("creating checkpoint dir {dir:?}"))?;
        let path = checkpoint::epoch_file(&dir, tag);
        let sw = Stopwatch::new();
        let (m, v, t) = self.opt.state();
        checkpoint::save(&path, &self.params, m, v, t, self.cfg.train.grad_mode, tag)?;
        let secs = sw.elapsed_secs();
        checkpoint::prune(&dir, keep)?;
        let ck = self.ckpt.as_mut().expect("checkpoint state present");
        ck.last_epoch = Some(tag);
        ck.virtual_since = 0.0;
        ck.steps_since = 0;
        Ok(secs)
    }

    /// Epochs completed so far (== the epoch tag the next
    /// `train_epoch` call will run).
    pub fn completed_epochs(&self) -> usize {
        self.epoch_counter
    }

    /// Resume an interrupted run from the newest checkpoint in `dir`
    /// (`kgscale train --resume <dir>`): restores params + optimizer
    /// state and fast-forwards the epoch counter so the next
    /// `train_epoch` continues where the interrupted run left off.
    /// Returns the number of completed epochs.
    pub fn resume_from_dir(&mut self, dir: &Path) -> Result<u64> {
        let (tag, path) = checkpoint::latest(dir)?
            .with_context(|| format!("no checkpoint found in {dir:?}"))?;
        let saved = self.restore_checkpoint(&path)?;
        anyhow::ensure!(
            saved == tag,
            "checkpoint {path:?} is tagged epoch {saved} inside but epoch {tag} by name"
        );
        self.epoch_counter = tag as usize;
        // If this run also checkpoints into the same directory, the
        // restored snapshot is its baseline — don't rewrite it.
        if let Some(ck) = &mut self.ckpt {
            if ck.dir == dir {
                ck.last_epoch = Some(tag);
                ck.virtual_since = 0.0;
                ck.steps_since = 0;
            }
        }
        crate::log_info!("resumed from {path:?}: {tag} epochs already complete");
        Ok(tag)
    }

    /// Record an external evaluation point (Figure 7 series).
    pub fn record_eval(&mut self, mrr: f64) {
        let t = self.history.total_virtual_secs();
        let epoch = self.epoch_counter;
        self.history.eval_points.push((t, epoch, mrr));
    }

    /// Record an evaluation point plus its timing breakdown (the
    /// overlapped-eval instrumentation in fig6b/fig7). The stats also
    /// stamp the most recent epoch's `eval_*` fields, so per-epoch
    /// reports can show what the periodic eval after that epoch cost.
    pub fn record_eval_stats(&mut self, mrr: f64, stats: &EvalStats) {
        self.record_eval(mrr);
        self.history.eval_stats.push(*stats);
        if let Some(e) = self.history.epochs.last_mut() {
            e.eval_wall_secs = stats.wall_secs;
            e.eval_rank_stall_secs = stats.rank_stall_secs;
            e.eval_overlap_efficiency = stats.overlap_efficiency;
        }
    }

    /// Save parameters + optimizer state, tagged with the gradient mode
    /// (so lazy-Adam moments are never silently resumed as dense ones)
    /// and the completed-epoch count.
    pub fn save_checkpoint(&self, path: &Path) -> Result<()> {
        let (m, v, t) = self.opt.state();
        checkpoint::save(
            path,
            &self.params,
            m,
            v,
            t,
            self.cfg.train.grad_mode,
            self.epoch_counter as u64,
        )
    }

    /// Restore a checkpoint into params + optimizer state. `dense` and
    /// `sparse` states are interchangeable (bit-identical paths); a
    /// `sparse_lazy` checkpoint only resumes under `sparse_lazy`, and
    /// vice versa. Returns the checkpoint's completed-epoch tag; the
    /// epoch counter is *not* moved (that's `resume_from_dir`'s job).
    pub fn restore_checkpoint(&mut self, path: &Path) -> Result<u64> {
        let ck = checkpoint::load(path)?;
        anyhow::ensure!(
            ck.params.len() == self.manifest.param_count,
            "checkpoint has {} params but manifest expects {}",
            ck.params.len(),
            self.manifest.param_count
        );
        checkpoint::check_grad_mode(ck.grad_mode, self.cfg.train.grad_mode)?;
        self.params = ck.params;
        self.opt.restore(ck.adam_m, ck.adam_v, ck.adam_t);
        Ok(ck.epoch)
    }
}

/// Execute prepared units in order on the coordinator thread (the only
/// place PJRT types are touched), accumulating loss and gradients into
/// `sink`. Returns (Σ loss, triple count, exec seconds).
#[allow(clippy::too_many_arguments)]
fn execute_units(
    units: &[PreparedUnit],
    manifest: &Manifest,
    runtime: &Runtime,
    params: &[f32],
    sink: &mut GradSink<'_>,
    grad_scratch: &mut Vec<f32>,
    train_seed: u64,
    epoch: usize,
) -> Result<(f64, f64, f64)> {
    let provided = manifest.mode == "provided";
    let seed = (train_seed as i32) ^ ((epoch as i32) << 10);
    let mut loss_sum = 0f64;
    let mut count = 0f64;
    let mut exec_secs = 0f64;
    for u in units {
        let exe = runtime.load(&u.file)?;
        let exec_sw = Stopwatch::new();
        let s = &u.scratch;
        let node_input = if provided {
            HostTensor::F32(&s.node_feat, &[u.nodes as i64, manifest.feature_dim as i64])
        } else {
            HostTensor::I32(&s.node_ids, &[u.nodes as i64])
        };
        let outputs = exe.run(&[
            HostTensor::F32(params, &[params.len() as i64]),
            node_input,
            HostTensor::I32(&s.src, &[u.edges as i64]),
            HostTensor::I32(&s.dst, &[u.edges as i64]),
            HostTensor::I32(&s.rel, &[u.edges as i64]),
            HostTensor::F32(&s.emask, &[u.edges as i64]),
            HostTensor::I32(&s.ts, &[u.triples as i64]),
            HostTensor::I32(&s.tr, &[u.triples as i64]),
            HostTensor::I32(&s.tt, &[u.triples as i64]),
            HostTensor::F32(&s.labels, &[u.triples as i64]),
            HostTensor::F32(&s.tmask, &[u.triples as i64]),
            HostTensor::ScalarI32(seed),
        ])?;
        exec_secs += exec_sw.elapsed_secs();
        anyhow::ensure!(outputs.len() == 2, "train_step returned {} outputs", outputs.len());
        loss_sum += literal_scalar_f32(&outputs[0])? as f64;
        // Readback reuses `grad_scratch`'s allocation (no per-batch Vec).
        literal_to_f32_into(&outputs[1], grad_scratch)?;
        anyhow::ensure!(
            grad_scratch.len() == manifest.param_count,
            "gradient length mismatch: {} vs {}",
            grad_scratch.len(),
            manifest.param_count
        );
        match sink {
            GradSink::Dense(acc) => {
                for (a, g) in acc.iter_mut().zip(grad_scratch.iter()) {
                    *a += g;
                }
            }
            // Only the touched entity rows + touched relation rows (+
            // the dense remainder) are accumulated: O(touched·dim +
            // remainder) instead of O(param_count).
            GradSink::Sparse(sg) => {
                sg.accumulate_with_rels(&u.cg.nodes_global, &u.cg.tr, grad_scratch)
            }
        }
        count += u.batch_len as f64;
    }
    Ok((loss_sum, count, exec_secs))
}

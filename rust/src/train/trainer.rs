//! The distributed trainer (paper §3.1/§3.3, Algorithm 1), executed on a
//! simulated cluster.
//!
//! Physical layout: everything runs on the coordinator thread (the xla
//! wrapper types are not Send and this machine has one core). Logical
//! layout: `P` workers, each bound to one self-sufficient partition,
//! advance in *synchronous steps*. Per step each active worker
//!
//!   1. extracts its edge mini-batch's compute graph (measured),
//!   2. executes the AOT `train_step` artifact → (Σ loss, Σ-gradients)
//!      (measured),
//!
//! then gradients are combined and one optimizer step is applied. The
//! virtual cluster clock advances by `max_w(compute_w) + T_sync` where
//! `T_sync` comes from the α-β network model (ring AllReduce by default)
//! — i.e. measured compute composed with modeled communication, which is
//! the documented substitution for the paper's 4×2-GPU cluster.
//!
//! Mathematical equivalence (§2.2): `train_step` returns the *sum* of
//! per-triple losses and its gradient; the trainer divides the summed
//! gradient by the global triple count. The result is bit-comparable to
//! a single worker processing the union batch — verified by the
//! `distributed_training_parity` and `gradient_modes_*` integration
//! tests. Because averaging makes all replicas identical after every
//! step, the coordinator stores the replica once and hands the same
//! vector to each logical worker.
//!
//! # Gradient modes (`train.grad_mode`)
//!
//! A mini-batch's compute graph touches only the `ent_emb` rows in its
//! `nodes_global` set; every other embedding row has an exactly-zero
//! gradient. The gradient path exploits this (DGL-KE, Zheng et al. 2020):
//!
//! - `dense` (default): the reference path. O(param_count) accumulator
//!   zero + add + Adam every step, dense sync bytes.
//! - `sparse`: row-sparse accumulation ([`SparseGrad`]) with *dense*
//!   Adam over the scattered average — **bit-identical** to `dense`
//!   (same losses, same parameters), but the per-step zero/accumulate
//!   cost is O(touched rows) and `grad_sync = "sparse"` may charge sync
//!   on the bytes that actually move.
//! - `sparse_lazy`: row-sparse accumulation + lazy Adam — moments and
//!   parameters update only at touched rows, making the optimizer step
//!   itself O(touched rows). **Not** bit-equivalent to `dense`
//!   (untouched rows skip moment decay; see `train::optimizer` docs);
//!   loss trajectories track the dense path closely.

use crate::config::{ExperimentConfig, GradMode, GradSync};
use crate::graph::KnowledgeGraph;
use crate::metrics::{ComponentTimes, EpochRecord, RunHistory};
use crate::model::{init_params, Manifest};
use crate::partition;
use crate::runtime::{literal_scalar_f32, literal_to_f32_into, HostTensor, Runtime};
use crate::sampler::batch::EpochBatches;
use crate::sampler::compute_graph::{ComputeGraph, ComputeGraphBuilder};
use crate::sampler::negative::{NegativeSampler, Scope};
use crate::sampler::{PartContext, TrainTriple};
use crate::train::checkpoint;
use crate::train::netsim::{NetworkModel, VirtualClock};
use crate::train::optimizer::Adam;
use crate::train::sparse::SparseGrad;
use crate::util::rng::Rng;
use crate::util::timer::Stopwatch;
use anyhow::Result;
use std::path::Path;

/// Reusable padded input buffers (no per-batch allocation on the hot path).
struct PadScratch {
    node_ids: Vec<i32>,
    node_feat: Vec<f32>,
    src: Vec<i32>,
    dst: Vec<i32>,
    rel: Vec<i32>,
    emask: Vec<f32>,
    ts: Vec<i32>,
    tr: Vec<i32>,
    tt: Vec<i32>,
    labels: Vec<f32>,
    tmask: Vec<f32>,
}

impl PadScratch {
    fn new() -> Self {
        PadScratch {
            node_ids: Vec::new(),
            node_feat: Vec::new(),
            src: Vec::new(),
            dst: Vec::new(),
            rel: Vec::new(),
            emask: Vec::new(),
            ts: Vec::new(),
            tr: Vec::new(),
            tt: Vec::new(),
            labels: Vec::new(),
            tmask: Vec::new(),
        }
    }

    /// Fill from a compute graph, padding to (n, e, b). `features` is
    /// the dataset's dense feature matrix (empty in embedding mode).
    fn fill(
        &mut self,
        cg: &ComputeGraph,
        features: &[f32],
        feature_dim: usize,
        n: usize,
        e: usize,
        b: usize,
    ) {
        assert!(cg.num_nodes() <= n && cg.num_edges() <= e && cg.num_triples() <= b);
        if feature_dim > 0 {
            let f = feature_dim;
            self.node_feat.clear();
            self.node_feat.resize(n * f, 0.0);
            for (i, &g) in cg.nodes_global.iter().enumerate() {
                let gi = g as usize * f;
                self.node_feat[i * f..(i + 1) * f].copy_from_slice(&features[gi..gi + f]);
            }
        } else {
            self.node_ids.clear();
            self.node_ids.resize(n, 0);
            for (i, &g) in cg.nodes_global.iter().enumerate() {
                self.node_ids[i] = g as i32;
            }
        }
        fill_pad_i32(&mut self.src, &cg.src, e, 0);
        fill_pad_i32(&mut self.dst, &cg.dst, e, 0);
        fill_pad_i32(&mut self.rel, &cg.rel, e, 0);
        fill_pad_f32(&mut self.emask, cg.num_edges(), e);
        fill_pad_i32(&mut self.ts, &cg.ts, b, 0);
        fill_pad_i32(&mut self.tr, &cg.tr, b, 0);
        fill_pad_i32(&mut self.tt, &cg.tt, b, 0);
        self.labels.clear();
        self.labels.extend_from_slice(&cg.labels);
        self.labels.resize(b, 0.0);
        fill_pad_f32(&mut self.tmask, cg.num_triples(), b);
    }
}

fn fill_pad_i32(dst: &mut Vec<i32>, src: &[i32], len: usize, pad: i32) {
    dst.clear();
    dst.extend_from_slice(src);
    dst.resize(len, pad);
}

fn fill_pad_f32(dst: &mut Vec<f32>, ones: usize, len: usize) {
    dst.clear();
    dst.resize(ones, 1.0);
    dst.resize(len, 0.0);
}

/// One logical trainer process bound to a partition.
struct Worker {
    ctx: PartContext,
    sampler: NegativeSampler,
    builder: ComputeGraphBuilder,
    scratch: PadScratch,
}

/// Per-step result of one worker's compute phase.
struct StepOutput {
    loss_sum: f64,
    count: f64,
    compute_secs: f64,
    cg_secs: f64,
    exec_secs: f64,
}

/// Where a worker batch's gradient readback is accumulated: the dense
/// reference accumulator, or the row-sparse one keyed off the compute
/// graph's `nodes_global` set.
enum GradSink<'a> {
    Dense(&'a mut Vec<f32>),
    Sparse(&'a mut SparseGrad),
}

pub struct Trainer<'rt> {
    pub cfg: ExperimentConfig,
    pub manifest: Manifest,
    runtime: &'rt Runtime,
    workers: Vec<Worker>,
    pub params: Vec<f32>,
    opt: Adam,
    net: NetworkModel,
    /// Dense gradient accumulator (`dense` mode) / all-zero scatter
    /// target (`sparse` mode). Empty in `sparse_lazy` mode, which never
    /// materializes a dense gradient.
    grads_accum: Vec<f32>,
    /// Row-sparse accumulator for the `sparse` / `sparse_lazy` modes.
    sparse_accum: Option<SparseGrad>,
    grad_scratch: Vec<f32>,
    /// Copy of the dataset's dense features (empty in embedding mode).
    features: Vec<f32>,
    feature_dim: usize,
    pub history: RunHistory,
    epoch_counter: usize,
}

impl<'rt> Trainer<'rt> {
    /// Partition the graph per the config and set up `num_trainers`
    /// logical workers.
    pub fn new(
        cfg: ExperimentConfig,
        graph: &KnowledgeGraph,
        runtime: &'rt Runtime,
        manifest: Manifest,
    ) -> Result<Self> {
        anyhow::ensure!(
            manifest.entities >= graph.num_entities,
            "manifest compiled for {} entities but dataset has {}",
            manifest.entities,
            graph.num_entities
        );
        let mut pcfg = cfg.partition.clone();
        pcfg.num_partitions = cfg.train.num_trainers;
        let parts = partition::partition_graph(graph, &pcfg, cfg.dataset.seed);
        let scope = if cfg.train.local_negatives { Scope::LocalCore } else { Scope::Global };
        let workers = parts
            .iter()
            .map(|p| {
                let ctx = PartContext::new(p);
                let sampler = NegativeSampler::new(&ctx, scope, graph.num_entities);
                let builder = ComputeGraphBuilder::new(&ctx);
                Worker { ctx, sampler, builder, scratch: PadScratch::new() }
            })
            .collect();
        if manifest.mode == "provided" {
            anyhow::ensure!(
                graph.feature_dim == manifest.feature_dim,
                "dataset feature_dim {} != manifest feature_dim {}",
                graph.feature_dim,
                manifest.feature_dim
            );
        }
        let params = init_params(&manifest, cfg.train.seed);
        let opt = Adam::from_config(manifest.param_count, &cfg.train);
        let net = NetworkModel::new(&cfg.network);
        // `sparse_lazy` never materializes a dense gradient, so skip the
        // param_count-sized allocation entirely.
        let grads_accum = match cfg.train.grad_mode {
            GradMode::SparseLazy => Vec::new(),
            _ => vec![0f32; manifest.param_count],
        };
        let sparse_accum = match cfg.train.grad_mode {
            GradMode::Dense => None,
            _ => {
                let seg = manifest.embedding_segment();
                if seg.is_none() {
                    crate::log_warn!(
                        "grad_mode {} without an ent_emb table (provided-features \
                         mode): the whole vector is treated as the dense tail",
                        cfg.train.grad_mode.name()
                    );
                }
                Some(SparseGrad::new(seg, manifest.param_count))
            }
        };
        let grad_scratch = Vec::with_capacity(manifest.param_count);
        let (features, feature_dim) = if manifest.mode == "provided" {
            (graph.features.clone(), graph.feature_dim)
        } else {
            (Vec::new(), 0)
        };
        // Pre-compile every train_step bucket so epoch timings measure
        // steady-state execution, not one-off PJRT compilation.
        for e in &manifest.entries {
            if let crate::model::EntryInfo::TrainStep { file, .. } = e {
                runtime.load(file)?;
            }
        }
        Ok(Trainer {
            cfg,
            manifest,
            runtime,
            workers,
            params,
            opt,
            net,
            grads_accum,
            sparse_accum,
            grad_scratch,
            features,
            feature_dim,
            history: RunHistory::default(),
            epoch_counter: 0,
        })
    }

    pub fn num_workers(&self) -> usize {
        self.workers.len()
    }

    /// Core-edge counts per worker (workload-balance diagnostics).
    pub fn worker_core_edges(&self) -> Vec<usize> {
        self.workers.iter().map(|w| w.ctx.core_edges.len()).collect()
    }

    /// Run one epoch of synchronous distributed training; returns the
    /// epoch record (also appended to `history`).
    pub fn train_epoch(&mut self) -> Result<EpochRecord> {
        let epoch = self.epoch_counter;
        self.epoch_counter += 1;
        let wall = Stopwatch::new();
        let mut clk = VirtualClock::new();
        let mut components = ComponentTimes::new();
        let p = self.workers.len();

        // Phase 1 (per paper Algorithm 1 line 3): every worker samples
        // its epoch negatives and builds its shuffled batch plan.
        let mut plans: Vec<Vec<Vec<TrainTriple>>> = Vec::with_capacity(p);
        let mut total_remote = 0usize;
        for (wid, w) in self.workers.iter_mut().enumerate() {
            let mut rng = Rng::seeded(
                self.cfg.train.seed ^ (epoch as u64) << 20 ^ (wid as u64) << 8 | 1,
            );
            let (negs, remote) =
                w.sampler.sample_epoch(&w.ctx, self.cfg.train.negatives_per_positive, &mut rng);
            total_remote += remote;
            let ep = EpochBatches::build(&w.ctx, negs, self.cfg.train.batch_edges, &mut rng);
            plans.push(ep.iter().map(|b| b.to_vec()).collect());
        }
        // Remote fetches (global-negative ablation) are charged to the
        // virtual clock: one embedding row per fetch.
        if total_remote > 0 {
            let bytes = self.manifest.embed_dim * 4;
            clk.advance(total_remote as f64 * self.net.fetch_secs(bytes));
        }

        let steps = plans.iter().map(|b| b.len()).max().unwrap_or(0);
        let mut loss_sum = 0f64;
        let mut count_sum = 0f64;
        let mut touched_sum = 0f64;
        let mut sync_bytes_sum = 0f64;

        for step in 0..steps {
            // Reset the step accumulator: O(param_count) only in dense
            // mode; the sparse modes clear just the previously-touched
            // rows + the small dense tail.
            match self.cfg.train.grad_mode {
                GradMode::Dense => self.grads_accum.fill(0.0),
                _ => self.sparse_accum.as_mut().expect("sparse accumulator").clear(),
            }
            let mut step_compute: Vec<f64> = Vec::with_capacity(p);
            let mut step_loss = 0f64;
            let mut step_count = 0f64;
            for wid in 0..p {
                let Some(batch) = plans[wid].get(step) else { continue };
                let mut sink = match self.cfg.train.grad_mode {
                    GradMode::Dense => GradSink::Dense(&mut self.grads_accum),
                    _ => GradSink::Sparse(
                        self.sparse_accum.as_mut().expect("sparse accumulator"),
                    ),
                };
                let out = run_worker_batch(
                    &mut self.workers[wid],
                    batch,
                    &self.cfg,
                    &self.manifest,
                    self.runtime,
                    &self.params,
                    &mut sink,
                    &mut self.grad_scratch,
                    (&self.features, self.feature_dim),
                    epoch,
                )?;
                step_loss += out.loss_sum;
                step_count += out.count;
                components.get_compute_graph.push(out.cg_secs);
                components.gnn_model.push(out.exec_secs);
                step_compute.push(out.compute_secs);
            }
            // Gradient averaging: modeled sync + measured optimizer step.
            // Sparse sync is charged on the bytes that actually move —
            // the union touched rows + dense tail — instead of the full
            // param_count * 4.
            let (sync_bytes, touched) = match &self.sparse_accum {
                Some(sg) if self.cfg.train.grad_sync == GradSync::Sparse => {
                    (sg.transfer_bytes(), sg.touched_rows())
                }
                Some(sg) => (self.manifest.param_count * 4, sg.touched_rows()),
                None => (self.manifest.param_count * 4, 0),
            };
            touched_sum += touched as f64;
            sync_bytes_sum += sync_bytes as f64;
            let sync_model_secs =
                self.net.sync_secs(self.cfg.train.grad_sync, sync_bytes, p);
            let opt_sw = Stopwatch::new();
            if step_count > 0.0 {
                let inv = (1.0 / step_count) as f32;
                match self.cfg.train.grad_mode {
                    GradMode::Dense => {
                        for g in self.grads_accum.iter_mut() {
                            *g *= inv;
                        }
                        self.opt.step(&mut self.params, &self.grads_accum);
                    }
                    GradMode::Sparse => {
                        // Scatter into the persistent all-zero dense
                        // vector and run the reference Adam: bit-identical
                        // to dense mode, O(touched) scatter + unscatter.
                        let sg = self.sparse_accum.as_mut().expect("sparse accumulator");
                        sg.scale(inv);
                        sg.scatter_into(&mut self.grads_accum);
                        self.opt.step(&mut self.params, &self.grads_accum);
                        sg.clear_scatter(&mut self.grads_accum);
                    }
                    GradMode::SparseLazy => {
                        let sg = self.sparse_accum.as_mut().expect("sparse accumulator");
                        sg.scale(inv);
                        self.opt.step_lazy(&mut self.params, sg);
                    }
                }
            }
            let opt_secs = opt_sw.elapsed_secs();
            components.sync_step.push(sync_model_secs + opt_secs);
            clk.step(&step_compute, sync_model_secs + opt_secs);
            loss_sum += step_loss;
            count_sum += step_count;
        }

        let record = EpochRecord {
            epoch,
            mean_loss: if count_sum > 0.0 { loss_sum / count_sum } else { f64::NAN },
            virtual_secs: clk.now(),
            wall_secs: wall.elapsed_secs(),
            num_steps: steps,
            avg_compute_graph: components.get_compute_graph.mean(),
            avg_gnn_model: components.gnn_model.mean(),
            avg_sync_step: components.sync_step.mean(),
            remote_fetches: total_remote,
            avg_touched_rows: if steps > 0 { touched_sum / steps as f64 } else { 0.0 },
            avg_sync_bytes: if steps > 0 { sync_bytes_sum / steps as f64 } else { 0.0 },
        };
        self.history.epochs.push(record.clone());
        Ok(record)
    }

    /// Record an external evaluation point (Figure 7 series).
    pub fn record_eval(&mut self, mrr: f64) {
        let t = self.history.total_virtual_secs();
        let epoch = self.epoch_counter;
        self.history.eval_points.push((t, epoch, mrr));
    }

    /// Save parameters + optimizer state, tagged with the gradient mode
    /// so lazy-Adam moments are never silently resumed as dense ones.
    pub fn save_checkpoint(&self, path: &Path) -> Result<()> {
        let (m, v, t) = self.opt.state();
        checkpoint::save(path, &self.params, m, v, t, self.cfg.train.grad_mode)
    }

    /// Restore a checkpoint. `dense` and `sparse` states are
    /// interchangeable (bit-identical paths); a `sparse_lazy` checkpoint
    /// only resumes under `sparse_lazy`, and vice versa.
    pub fn restore_checkpoint(&mut self, path: &Path) -> Result<()> {
        let ck = checkpoint::load(path)?;
        anyhow::ensure!(
            ck.params.len() == self.manifest.param_count,
            "checkpoint has {} params but manifest expects {}",
            ck.params.len(),
            self.manifest.param_count
        );
        let ck_lazy = ck.grad_mode == GradMode::SparseLazy;
        let now_lazy = self.cfg.train.grad_mode == GradMode::SparseLazy;
        anyhow::ensure!(
            ck_lazy == now_lazy,
            "checkpoint was written under grad_mode \"{}\" but this trainer runs \
             \"{}\" — lazy-Adam moments are not interchangeable with dense ones",
            ck.grad_mode.name(),
            self.cfg.train.grad_mode.name()
        );
        self.params = ck.params;
        self.opt.restore(ck.adam_m, ck.adam_v, ck.adam_t);
        Ok(())
    }
}

/// Run one worker's batch (with recursive split if the compute graph
/// exceeds every compiled bucket), accumulating gradients and loss into
/// `sink`.
#[allow(clippy::too_many_arguments)]
fn run_worker_batch(
    w: &mut Worker,
    batch: &[TrainTriple],
    cfg: &ExperimentConfig,
    manifest: &Manifest,
    runtime: &Runtime,
    params: &[f32],
    sink: &mut GradSink<'_>,
    grad_scratch: &mut Vec<f32>,
    features: (&[f32], usize),
    epoch: usize,
) -> Result<StepOutput> {
    let hops = manifest.num_layers;
    let relations = manifest.relations;
    let cg_sw = Stopwatch::new();
    let cg = w.builder.build(&w.ctx, batch, hops, relations);
    let cg_secs = cg_sw.elapsed_secs();

    let bucket = manifest.pick_train_bucket(cg.num_nodes(), cg.num_edges(), cg.num_triples());
    let Some(crate::model::EntryInfo::TrainStep { file, nodes, edges, triples }) = bucket else {
        // No bucket fits: split the batch and recurse (sum-losses make
        // this exactly equivalent).
        anyhow::ensure!(
            batch.len() > 1,
            "compute graph of a single triple (n={}, e={}) exceeds all compiled buckets — \
             re-run `kgscale plan` + `make artifacts`",
            cg.num_nodes(),
            cg.num_edges()
        );
        crate::log_warn!(
            "batch of {} triples overflows buckets (n={} e={}); splitting",
            batch.len(),
            cg.num_nodes(),
            cg.num_edges()
        );
        let mid = batch.len() / 2;
        let a = run_worker_batch(
            w, &batch[..mid], cfg, manifest, runtime, params, sink, grad_scratch,
            features, epoch,
        )?;
        let b = run_worker_batch(
            w, &batch[mid..], cfg, manifest, runtime, params, sink, grad_scratch,
            features, epoch,
        )?;
        return Ok(StepOutput {
            loss_sum: a.loss_sum + b.loss_sum,
            count: a.count + b.count,
            compute_secs: a.compute_secs + b.compute_secs + cg_secs,
            cg_secs: a.cg_secs + b.cg_secs + cg_secs,
            exec_secs: a.exec_secs + b.exec_secs,
        });
    };
    let (file, nodes, edges, triples) = (file.clone(), *nodes, *edges, *triples);

    let provided = manifest.mode == "provided";
    w.scratch.fill(&cg, features.0, features.1, nodes, edges, triples);

    let exe = runtime.load(&file)?;
    let exec_sw = Stopwatch::new();
    let seed = (cfg.train.seed as i32) ^ ((epoch as i32) << 10);
    let s = &w.scratch;
    let node_input = if provided {
        HostTensor::F32(&s.node_feat, &[nodes as i64, manifest.feature_dim as i64])
    } else {
        HostTensor::I32(&s.node_ids, &[nodes as i64])
    };
    let outputs = exe.run(&[
        HostTensor::F32(params, &[params.len() as i64]),
        node_input,
        HostTensor::I32(&s.src, &[edges as i64]),
        HostTensor::I32(&s.dst, &[edges as i64]),
        HostTensor::I32(&s.rel, &[edges as i64]),
        HostTensor::F32(&s.emask, &[edges as i64]),
        HostTensor::I32(&s.ts, &[triples as i64]),
        HostTensor::I32(&s.tr, &[triples as i64]),
        HostTensor::I32(&s.tt, &[triples as i64]),
        HostTensor::F32(&s.labels, &[triples as i64]),
        HostTensor::F32(&s.tmask, &[triples as i64]),
        HostTensor::ScalarI32(seed),
    ])?;
    let exec_secs = exec_sw.elapsed_secs();
    anyhow::ensure!(outputs.len() == 2, "train_step returned {} outputs", outputs.len());
    let loss_sum = literal_scalar_f32(&outputs[0])? as f64;
    // Readback reuses `grad_scratch`'s allocation (no per-batch Vec).
    literal_to_f32_into(&outputs[1], grad_scratch)?;
    anyhow::ensure!(
        grad_scratch.len() == manifest.param_count,
        "gradient length mismatch: {} vs {}",
        grad_scratch.len(),
        manifest.param_count
    );
    match sink {
        GradSink::Dense(acc) => {
            for (a, g) in acc.iter_mut().zip(grad_scratch.iter()) {
                *a += g;
            }
        }
        // Only the compute graph's touched rows (+ the dense tail) are
        // accumulated: O(touched·dim + tail) instead of O(param_count).
        GradSink::Sparse(sg) => sg.accumulate(&cg.nodes_global, grad_scratch),
    }
    Ok(StepOutput {
        loss_sum,
        count: batch.len() as f64,
        compute_secs: cg_secs + exec_secs,
        cg_secs,
        exec_secs,
    })
}

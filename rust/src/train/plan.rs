//! Artifact planning: measure the exact padded sizes every trainer
//! configuration will need, so `aot.py` compiles tight buckets.
//!
//! Padding is pure waste on the XLA side (masked edges still flow through
//! the message kernel), and the paper's speedup *mechanism* is that
//! smaller partitions mean smaller per-batch compute — so buckets must
//! track real partition sizes or the distributed speedup signal would be
//! padded away. `kgscale plan` runs the full partition + negative-sample
//! + batch + compute-graph pipeline for each trainer count (no XLA
//! involved), records the maxima, and emits the plan JSON that
//! `python -m compile.aot` consumes.

use crate::config::ExperimentConfig;
use crate::graph::KnowledgeGraph;
use crate::partition;
use crate::sampler::batch::EpochBatches;
use crate::sampler::compute_graph::ComputeGraphBuilder;
use crate::sampler::negative::{NegativeSampler, Scope};
use crate::sampler::PartContext;
use crate::util::json::Json;
use crate::util::rng::Rng;
use anyhow::Result;

/// Kernel block granularities — keep in sync with python/compile/aot.py.
pub const EDGE_BLOCK: usize = 512;
pub const TRIPLE_BLOCK: usize = 1024;
/// Headroom over the dry-run maxima: later epochs reshuffle batches, so
/// compute-graph sizes wander a little around the measured peak.
const MARGIN: f64 = 1.10;

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct Bucket {
    pub nodes: usize,
    pub edges: usize,
    pub triples: usize,
}

fn round_up(x: usize, m: usize) -> usize {
    x.div_ceil(m) * m
}

fn pad_bucket(nodes: usize, edges: usize, triples: usize) -> Bucket {
    Bucket {
        nodes: round_up(((nodes as f64) * MARGIN) as usize + 1, 64),
        edges: round_up(((edges as f64) * MARGIN) as usize + 1, EDGE_BLOCK),
        triples: round_up(((triples as f64) * MARGIN) as usize + 1, TRIPLE_BLOCK),
    }
}

/// The artifact plan for one dataset tier.
#[derive(Clone, Debug)]
pub struct ArtifactPlan {
    pub train_buckets: Vec<Bucket>,
    pub encode_nodes: usize,
    pub encode_edges: usize,
    pub score_queries: usize,
}

/// Dry-run one epoch per trainer count and collect bucket maxima.
pub fn plan_buckets(
    cfg: &ExperimentConfig,
    graph: &KnowledgeGraph,
    trainer_counts: &[usize],
) -> Result<ArtifactPlan> {
    let mut buckets: Vec<Bucket> = Vec::new();
    for &p in trainer_counts {
        let mut pcfg = cfg.partition.clone();
        pcfg.num_partitions = p;
        let parts = partition::partition_graph(graph, &pcfg, cfg.dataset.seed);
        let mut max_n = 0usize;
        let mut max_e = 0usize;
        let mut max_b = 0usize;
        for part in &parts {
            let ctx = PartContext::new(part);
            let sampler = NegativeSampler::new(&ctx, Scope::LocalCore, graph.num_entities);
            let mut builder = ComputeGraphBuilder::new(&ctx);
            let mut rng = Rng::seeded(cfg.train.seed ^ 0xB0C5);
            let (negs, _) =
                sampler.sample_epoch(&ctx, cfg.train.negatives_per_positive, &mut rng);
            let ep = EpochBatches::build(&ctx, negs, cfg.train.batch_edges, &mut rng);
            for batch in ep.iter() {
                let cg = builder.build(&ctx, batch, cfg.model.num_layers, graph.num_relations);
                max_n = max_n.max(cg.num_nodes());
                max_e = max_e.max(cg.num_edges());
                max_b = max_b.max(cg.num_triples());
            }
        }
        let b = pad_bucket(max_n, max_e, max_b);
        crate::log_info!(
            "plan[{}] P={p}: max cg nodes={max_n} edges={max_e} triples={max_b} -> bucket {b:?}",
            cfg.name
        );
        if !buckets.contains(&b) {
            buckets.push(b);
        }
    }
    // Merge near-duplicate buckets: drop any bucket dominated by another
    // within 15% on every axis (compile time is precious on one core).
    let mut keep: Vec<Bucket> = Vec::new();
    for b in &buckets {
        let dominated = buckets.iter().any(|o| {
            o != b
                && o.nodes >= b.nodes
                && o.edges >= b.edges
                && o.triples >= b.triples
                && (o.edges as f64) <= b.edges as f64 * 1.15
                && (o.triples as f64) <= b.triples as f64 * 1.15
        });
        if !dominated && !keep.contains(b) {
            keep.push(*b);
        }
    }
    keep.sort_by_key(|b| (b.edges, b.triples));

    // Full-graph encode sizes: all entities + both message directions of
    // every train edge (exact; encode always runs the same shape).
    let encode_nodes = round_up(graph.num_entities, 64);
    let encode_edges = round_up(2 * graph.train.len(), EDGE_BLOCK);
    Ok(ArtifactPlan {
        train_buckets: keep,
        encode_nodes,
        encode_edges,
        score_queries: 512,
    })
}

/// Serialize the plan to the JSON `python -m compile.aot --plan` expects.
pub fn plan_to_json(cfg: &ExperimentConfig, plan: &ArtifactPlan) -> Json {
    let mode = if cfg.dataset.feature_dim > 0 { "provided" } else { "embedding" };
    Json::obj(vec![
        ("name", Json::Str(cfg.name.clone())),
        ("mode", Json::Str(mode.into())),
        ("entities", Json::Num(cfg.dataset.entities as f64)),
        ("relations", Json::Num(cfg.dataset.relations as f64)),
        ("embed_dim", Json::Num(cfg.model.embed_dim as f64)),
        ("num_bases", Json::Num(cfg.model.num_bases as f64)),
        ("num_layers", Json::Num(cfg.model.num_layers as f64)),
        ("feature_dim", Json::Num(cfg.dataset.feature_dim as f64)),
        ("dropout", Json::Num(cfg.model.dropout)),
        (
            "train_buckets",
            Json::Arr(
                plan.train_buckets
                    .iter()
                    .map(|b| Json::arr_usize(&[b.nodes, b.edges, b.triples]))
                    .collect(),
            ),
        ),
        ("encode", Json::arr_usize(&[plan.encode_nodes, plan.encode_edges])),
        ("score_queries", Json::Num(plan.score_queries as f64)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExperimentConfig;
    use crate::graph::generator;

    #[test]
    fn plan_covers_every_trainer_count() {
        let cfg = ExperimentConfig::tiny();
        let g = generator::generate(&cfg.dataset);
        let plan = plan_buckets(&cfg, &g, &[1, 2, 4]).unwrap();
        assert!(!plan.train_buckets.is_empty());
        // Full-batch tiny: the largest bucket must fit the whole graph's
        // message set (2 * train edges) with margin.
        let max_edges = plan.train_buckets.iter().map(|b| b.edges).max().unwrap();
        assert!(max_edges >= 2 * g.train.len());
        assert!(plan.encode_nodes >= g.num_entities);
        assert!(plan.encode_edges >= 2 * g.train.len());
    }

    #[test]
    fn buckets_are_block_aligned() {
        let cfg = ExperimentConfig::tiny();
        let g = generator::generate(&cfg.dataset);
        let plan = plan_buckets(&cfg, &g, &[1, 2]).unwrap();
        for b in &plan.train_buckets {
            assert_eq!(b.edges % EDGE_BLOCK, 0);
            assert_eq!(b.triples % TRIPLE_BLOCK, 0);
            assert_eq!(b.nodes % 64, 0);
        }
        assert_eq!(plan.encode_edges % EDGE_BLOCK, 0);
    }

    #[test]
    fn plan_json_has_required_keys() {
        let cfg = ExperimentConfig::tiny();
        let g = generator::generate(&cfg.dataset);
        let plan = plan_buckets(&cfg, &g, &[1]).unwrap();
        let j = plan_to_json(&cfg, &plan);
        for key in ["name", "mode", "entities", "train_buckets", "encode", "score_queries"] {
            assert!(j.get(key).is_some(), "missing {key}");
        }
        assert_eq!(j.req_str("mode").unwrap(), "embedding");
    }

    #[test]
    fn round_up_behaviour() {
        assert_eq!(round_up(1, 512), 512);
        assert_eq!(round_up(512, 512), 512);
        assert_eq!(round_up(513, 512), 1024);
    }
}

//! Ring AllReduce — a faithful in-process implementation of the chunked
//! reduce-scatter + all-gather algorithm (the operation Gloo performs for
//! PyTorch DDP, paper §2.2/§3.1).
//!
//! The virtual-clock trainer does not need to *move* bytes (all replicas
//! live in one address space and weighted averaging is associative), but
//! this module exists for three reasons:
//!
//! 1. it is the correctness oracle — tests prove the chunked ring
//!    produces bit-identical results to a serial sum, the mathematical
//!    equivalence the paper's §2.2 requires;
//! 2. the `allreduce` bench measures its real memory-bandwidth cost and
//!    compares ring vs parameter-server aggregation shapes;
//! 3. ablations can run the trainer through it to include real (not
//!    modeled) reduction cost.

/// In-place ring AllReduce over `p` worker gradient buffers: afterwards
/// every buffer holds the element-wise SUM of all inputs.
///
/// Implements the textbook schedule: buffers are cut into `p` chunks;
/// during reduce-scatter step s, worker w adds its chunk
/// `(w - s - 1) mod p` into worker `(w + 1) mod p`'s copy; after p-1
/// steps worker w owns the full sum of chunk `(w + 1) mod p`; all-gather
/// then rotates the finished chunks around the ring.
pub fn ring_allreduce_sum(buffers: &mut [Vec<f32>]) {
    let p = buffers.len();
    if p <= 1 {
        return;
    }
    let n = buffers[0].len();
    assert!(buffers.iter().all(|b| b.len() == n), "mismatched gradient sizes");
    if n == 0 {
        return;
    }
    let chunk_bounds = |c: usize| -> (usize, usize) {
        let lo = c * n / p;
        let hi = (c + 1) * n / p;
        (lo, hi)
    };

    // Reduce-scatter: p-1 rounds. In round s, worker w sends chunk
    // (w - s) mod p to worker (w + 1) mod p, which accumulates it.
    for s in 0..p - 1 {
        for w in 0..p {
            let src_worker = w;
            let dst_worker = (w + 1) % p;
            let c = (w + p - s) % p;
            let (lo, hi) = chunk_bounds(c);
            // Split-borrow the two workers' buffers.
            let (a, b) = if src_worker < dst_worker {
                let (left, right) = buffers.split_at_mut(dst_worker);
                (&left[src_worker][lo..hi], &mut right[0][lo..hi])
            } else {
                let (left, right) = buffers.split_at_mut(src_worker);
                let dst = &mut left[dst_worker];
                (&right[0][lo..hi], &mut dst[lo..hi])
            };
            for (d, s_) in b.iter_mut().zip(a.iter()) {
                *d += s_;
            }
        }
    }

    // After reduce-scatter, worker w holds the complete sum of chunk
    // (w + 1) mod p. All-gather: rotate complete chunks around the ring.
    for s in 0..p - 1 {
        for w in 0..p {
            let src_worker = w;
            let dst_worker = (w + 1) % p;
            let c = (w + 1 + p - s) % p;
            let (lo, hi) = chunk_bounds(c);
            let (a, b) = if src_worker < dst_worker {
                let (left, right) = buffers.split_at_mut(dst_worker);
                (&left[src_worker][lo..hi], &mut right[0][lo..hi])
            } else {
                let (left, right) = buffers.split_at_mut(src_worker);
                let dst = &mut left[dst_worker];
                (&right[0][lo..hi], &mut dst[lo..hi])
            };
            b.copy_from_slice(a);
        }
    }
}

/// AllReduce to the MEAN (the DDP semantic): sum then scale by 1/p.
pub fn ring_allreduce_mean(buffers: &mut [Vec<f32>]) {
    let p = buffers.len() as f32;
    ring_allreduce_sum(buffers);
    for b in buffers.iter_mut() {
        for v in b.iter_mut() {
            *v /= p;
        }
    }
}

/// Parameter-server aggregation baseline: worker 0 acts as the server.
/// Same result, different (serialized) data movement — benched against
/// the ring in `benches/allreduce.rs`.
pub fn param_server_sum(buffers: &mut [Vec<f32>]) {
    let p = buffers.len();
    if p <= 1 {
        return;
    }
    let (server, rest) = buffers.split_at_mut(1);
    for b in rest.iter() {
        for (d, s) in server[0].iter_mut().zip(b.iter()) {
            *d += s;
        }
    }
    for b in rest.iter_mut() {
        b.copy_from_slice(&server[0]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_buffers(p: usize, n: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Rng::seeded(seed);
        (0..p)
            .map(|_| (0..n).map(|_| rng.uniform_f32(-1.0, 1.0)).collect())
            .collect()
    }

    fn serial_sum(buffers: &[Vec<f32>]) -> Vec<f32> {
        let n = buffers[0].len();
        let mut out = vec![0f32; n];
        for b in buffers {
            for (o, x) in out.iter_mut().zip(b.iter()) {
                *o += x;
            }
        }
        out
    }

    #[test]
    fn ring_equals_serial_sum_various_p_and_n() {
        for (p, n) in [(2, 10), (3, 7), (4, 64), (5, 1), (8, 1000), (7, 13)] {
            let mut bufs = random_buffers(p, n, p as u64 * 31 + n as u64);
            let want = serial_sum(&bufs);
            ring_allreduce_sum(&mut bufs);
            for (w, b) in bufs.iter().enumerate() {
                for (i, (&got, &wv)) in b.iter().zip(&want).enumerate() {
                    assert!(
                        (got - wv).abs() <= 1e-4 * wv.abs().max(1.0),
                        "p={p} n={n} worker {w} elem {i}: {got} != {wv}"
                    );
                }
            }
        }
    }

    #[test]
    fn all_replicas_identical_after_ring() {
        let mut bufs = random_buffers(6, 101, 9);
        ring_allreduce_sum(&mut bufs);
        for w in 1..bufs.len() {
            assert_eq!(bufs[0], bufs[w], "replica {w} diverged");
        }
    }

    #[test]
    fn mean_scales_sum() {
        let mut bufs = vec![vec![2.0f32; 8], vec![4.0f32; 8]];
        ring_allreduce_mean(&mut bufs);
        assert!(bufs[0].iter().all(|&x| (x - 3.0).abs() < 1e-6));
    }

    #[test]
    fn param_server_matches_ring() {
        let mut a = random_buffers(5, 37, 3);
        let mut b = a.clone();
        ring_allreduce_sum(&mut a);
        param_server_sum(&mut b);
        for (x, y) in a[0].iter().zip(&b[0]) {
            assert!((x - y).abs() <= 1e-4 * x.abs().max(1.0));
        }
    }

    #[test]
    fn single_worker_and_empty_are_noops() {
        let mut one = vec![vec![1.0f32, 2.0]];
        ring_allreduce_sum(&mut one);
        assert_eq!(one[0], vec![1.0, 2.0]);
        let mut empty: Vec<Vec<f32>> = vec![vec![], vec![]];
        ring_allreduce_sum(&mut empty);
    }

    #[test]
    fn n_smaller_than_p_still_correct() {
        let mut bufs = random_buffers(8, 3, 5);
        let want = serial_sum(&bufs);
        ring_allreduce_sum(&mut bufs);
        for b in &bufs {
            for (got, wv) in b.iter().zip(&want) {
                assert!((got - wv).abs() < 1e-5);
            }
        }
    }
}

//! Distributed training (paper §3): synchronous data-parallel workers on
//! self-sufficient partitions, ring-AllReduce gradient sharing, Adam.
//!
//! Cluster simulation: compute is measured, communication is modeled
//! ([`netsim`]) — see DESIGN.md "Substitutions". [`allreduce`] carries a
//! faithful chunked ring implementation used as the correctness oracle
//! and for bandwidth benches; [`plan`] sizes the AOT buckets; [`sparse`]
//! is the row-sparse gradient representation behind the `sparse` /
//! `sparse_lazy` gradient modes; [`pipeline`] is the multi-threaded host
//! data path that overlaps batch prep with XLA execution; [`faults`]
//! injects seeded crash/straggler/link events that [`trainer`] (and the
//! crash-consistent [`checkpoint`] format) recovers from; and
//! [`trainer`] is Algorithm 1.

pub mod allreduce;
pub mod checkpoint;
pub mod faults;
pub mod netsim;
pub mod optimizer;
pub mod pipeline;
pub mod plan;
pub mod sparse;
pub mod trainer;

pub use faults::{EpochFaults, FaultPlan};
pub use netsim::{NetworkModel, VirtualClock};
pub use optimizer::Adam;
pub use pipeline::{worker_epoch_seed, HostPool};
pub use sparse::SparseGrad;
pub use trainer::Trainer;

//! Adam optimizer over the flat parameter vector.
//!
//! The optimizer lives in Rust (L3 owns parameter state; XLA computes
//! gradients), runs once per synchronous step on the globally-averaged
//! gradient, and is fully deterministic. Standard Adam (Kingma & Ba)
//! with bias correction.
//!
//! # Gradient-mode semantics (see also `train::trainer`)
//!
//! - **dense** (`Adam::step`): the reference path. Every parameter gets a
//!   moment update each step, even where the gradient is zero (moments
//!   decay, so stale momentum still nudges untouched rows).
//! - **sparse** accumulation + dense Adam: the trainer accumulates
//!   row-sparsely and scatters into a zeroed dense vector before calling
//!   `Adam::step` — *bit-identical* to dense, because untouched rows have
//!   exactly-zero gradients either way.
//! - **sparse_lazy** (`Adam::step_lazy`): DGL-KE-style lazy Adam. Moments
//!   and parameters are updated *only* for touched entity-embedding and
//!   relation-decoder rows (plus the whole dense remainder). This
//!   deviates from dense Adam: untouched
//!   rows receive neither moment decay nor stale-momentum updates, and
//!   the bias correction uses the global step count `t` for all rows (as
//!   in TF LazyAdam / DGL-KE). Loss trajectories track the dense path
//!   closely but are not bit-equivalent.
//! - SGD has no moments, so `Sgd::step_sparse` *is* bit-identical to
//!   `Sgd::step` on row-sparse gradients.

use crate::train::sparse::SparseGrad;

#[derive(Clone, Debug)]
pub struct Adam {
    pub lr: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    m: Vec<f32>,
    v: Vec<f32>,
    t: u64,
}

impl Adam {
    pub fn new(param_count: usize, lr: f64, beta1: f64, beta2: f64, eps: f64) -> Self {
        Adam {
            lr: lr as f32,
            beta1: beta1 as f32,
            beta2: beta2 as f32,
            eps: eps as f32,
            m: vec![0.0; param_count],
            v: vec![0.0; param_count],
            t: 0,
        }
    }

    pub fn from_config(param_count: usize, cfg: &crate::config::TrainConfig) -> Self {
        Self::new(param_count, cfg.lr, cfg.adam_beta1, cfg.adam_beta2, cfg.adam_eps)
    }

    /// One update: `params -= lr * m̂ / (sqrt(v̂) + eps)`.
    pub fn step(&mut self, params: &mut [f32], grads: &[f32]) {
        assert_eq!(params.len(), self.m.len());
        assert_eq!(grads.len(), self.m.len());
        self.t += 1;
        let bc1 = 1.0 - self.beta1.powi(self.t as i32);
        let bc2 = 1.0 - self.beta2.powi(self.t as i32);
        // Fold the bias corrections into a single scalar multiplier so the
        // inner loop is 2 fma + 1 sqrt per element.
        let lr_t = self.lr * bc2.sqrt() / bc1;
        let (b1, b2, eps) = (self.beta1, self.beta2, self.eps);
        for i in 0..params.len() {
            let g = grads[i];
            let m = b1 * self.m[i] + (1.0 - b1) * g;
            let v = b2 * self.v[i] + (1.0 - b2) * g * g;
            self.m[i] = m;
            self.v[i] = v;
            params[i] -= lr_t * m / (v.sqrt() + eps);
        }
    }

    /// Lazy (row-sparse) update: advances `t`, then updates moments and
    /// parameters only at the gradient's touched entity rows, touched
    /// relation rows, and its dense remainder — O(touched·dim + tail)
    /// instead of O(param_count). See the module docs for the documented
    /// deviation from dense Adam.
    pub fn step_lazy(&mut self, params: &mut [f32], grads: &SparseGrad) {
        assert_eq!(params.len(), self.m.len());
        assert_eq!(grads.param_count(), self.m.len());
        self.t += 1;
        let bc1 = 1.0 - self.beta1.powi(self.t as i32);
        let bc2 = 1.0 - self.beta2.powi(self.t as i32);
        let lr_t = self.lr * bc2.sqrt() / bc1;
        let (b1, b2, eps) = (self.beta1, self.beta2, self.eps);
        let mut update = |i: usize, g: f32, params: &mut [f32]| {
            let m = b1 * self.m[i] + (1.0 - b1) * g;
            let v = b2 * self.v[i] + (1.0 - b2) * g * g;
            self.m[i] = m;
            self.v[i] = v;
            params[i] -= lr_t * m / (v.sqrt() + eps);
        };
        let seg = grads.segment();
        for (si, &row) in grads.touched().iter().enumerate() {
            let base = seg.offset + row as usize * seg.dim;
            for (d, &g) in grads.row(si).iter().enumerate() {
                update(base + d, g, params);
            }
        }
        let rseg = grads.relation_segment();
        for (si, &row) in grads.touched_rels().iter().enumerate() {
            let base = rseg.offset + row as usize * rseg.dim;
            for (d, &g) in grads.rel_row(si).iter().enumerate() {
                update(base + d, g, params);
            }
        }
        for (di, &g) in grads.dense().iter().enumerate() {
            update(grads.dense_param_index(di), g, params);
        }
    }

    pub fn steps_taken(&self) -> u64 {
        self.t
    }

    /// Reset moments (used when reusing a trainer across experiments).
    pub fn reset(&mut self) {
        self.m.fill(0.0);
        self.v.fill(0.0);
        self.t = 0;
    }

    /// Raw state access for checkpointing.
    pub fn state(&self) -> (&[f32], &[f32], u64) {
        (&self.m, &self.v, self.t)
    }

    pub fn restore(&mut self, m: Vec<f32>, v: Vec<f32>, t: u64) {
        assert_eq!(m.len(), self.m.len());
        assert_eq!(v.len(), self.v.len());
        self.m = m;
        self.v = v;
        self.t = t;
    }
}

/// Plain SGD — the ablation/debug optimizer.
#[derive(Clone, Debug)]
pub struct Sgd {
    pub lr: f32,
}

impl Sgd {
    pub fn step(&self, params: &mut [f32], grads: &[f32]) {
        for (p, g) in params.iter_mut().zip(grads) {
            *p -= self.lr * g;
        }
    }

    /// Row-sparse step. SGD is stateless, so skipping zero-gradient rows
    /// changes nothing: bit-identical to [`step`](Self::step) on the
    /// scattered dense gradient.
    pub fn step_sparse(&self, params: &mut [f32], grads: &SparseGrad) {
        assert_eq!(params.len(), grads.param_count());
        let seg = grads.segment();
        for (si, &row) in grads.touched().iter().enumerate() {
            let base = seg.offset + row as usize * seg.dim;
            for (d, &g) in grads.row(si).iter().enumerate() {
                params[base + d] -= self.lr * g;
            }
        }
        let rseg = grads.relation_segment();
        for (si, &row) in grads.touched_rels().iter().enumerate() {
            let base = rseg.offset + row as usize * rseg.dim;
            for (d, &g) in grads.rel_row(si).iter().enumerate() {
                params[base + d] -= self.lr * g;
            }
        }
        for (di, &g) in grads.dense().iter().enumerate() {
            params[grads.dense_param_index(di)] -= self.lr * g;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Adam on f(x) = x² must converge toward 0 from any start.
    #[test]
    fn adam_minimizes_quadratic() {
        let mut adam = Adam::new(1, 0.1, 0.9, 0.999, 1e-8);
        let mut params = vec![3.0f32];
        for _ in 0..200 {
            let g = vec![2.0 * params[0]];
            adam.step(&mut params, &g);
        }
        assert!(params[0].abs() < 0.05, "did not converge: {}", params[0]);
    }

    #[test]
    fn first_step_magnitude_is_lr() {
        // With bias correction, the first Adam step ≈ lr * sign(g).
        let mut adam = Adam::new(3, 0.01, 0.9, 0.999, 1e-8);
        let mut params = vec![1.0f32, -2.0, 0.5];
        adam.step(&mut params, &[0.3, -0.7, 100.0]);
        assert!((params[0] - (1.0 - 0.01)).abs() < 1e-4);
        assert!((params[1] - (-2.0 + 0.01)).abs() < 1e-4);
        assert!((params[2] - (0.5 - 0.01)).abs() < 1e-4);
    }

    #[test]
    fn deterministic_across_instances() {
        let mut a = Adam::new(4, 0.05, 0.9, 0.999, 1e-8);
        let mut b = Adam::new(4, 0.05, 0.9, 0.999, 1e-8);
        let mut pa = vec![1.0, 2.0, 3.0, 4.0];
        let mut pb = pa.clone();
        for i in 0..10 {
            let g: Vec<f32> = (0..4).map(|j| ((i + j) as f32).sin()).collect();
            a.step(&mut pa, &g);
            b.step(&mut pb, &g);
        }
        assert_eq!(pa, pb);
    }

    #[test]
    fn reset_and_restore_roundtrip() {
        let mut adam = Adam::new(2, 0.1, 0.9, 0.999, 1e-8);
        let mut p = vec![1.0f32, 1.0];
        adam.step(&mut p, &[0.1, 0.2]);
        let (m, v, t) = adam.state();
        let (m, v) = (m.to_vec(), v.to_vec());
        assert_eq!(t, 1);
        adam.reset();
        assert_eq!(adam.steps_taken(), 0);
        adam.restore(m, v, t);
        assert_eq!(adam.steps_taken(), 1);
    }

    #[test]
    fn sgd_step_is_linear() {
        let sgd = Sgd { lr: 0.5 };
        let mut p = vec![1.0f32, 2.0];
        sgd.step(&mut p, &[1.0, -2.0]);
        assert_eq!(p, vec![0.5, 3.0]);
    }

    use crate::model::EmbeddingSegment;
    use crate::train::sparse::SparseGrad;

    /// 5 embedding rows × 2 dims at offset 0, then a 3-float tail.
    fn sparse_fixture(touched: &[u32], salt: f32) -> (SparseGrad, Vec<f32>, usize) {
        let seg = EmbeddingSegment { offset: 0, rows: 5, dim: 2 };
        let pc = 10 + 3;
        let mut flat = vec![0.0f32; pc];
        for &r in touched {
            flat[r as usize * 2] = salt + r as f32;
            flat[r as usize * 2 + 1] = -salt * 0.5;
        }
        for i in 10..13 {
            flat[i] = salt * 0.25 * (i as f32 - 9.0);
        }
        let mut sg = SparseGrad::new(Some(seg), pc);
        sg.accumulate(touched, &flat);
        (sg, flat, pc)
    }

    /// Sparse SGD must be bit-identical to dense SGD on the same
    /// row-sparse gradient.
    #[test]
    fn sparse_sgd_bit_identical_to_dense() {
        let (sg, flat, pc) = sparse_fixture(&[1, 3], 0.75);
        let sgd = Sgd { lr: 0.1 };
        let mut p_dense: Vec<f32> = (0..pc).map(|i| i as f32 * 0.5).collect();
        let mut p_sparse = p_dense.clone();
        sgd.step(&mut p_dense, &flat);
        sgd.step_sparse(&mut p_sparse, &sg);
        assert_eq!(p_dense, p_sparse);
    }

    /// Lazy Adam matches dense Adam exactly on touched rows + tail, and
    /// leaves untouched rows exactly alone (the documented deviation).
    #[test]
    fn lazy_adam_touched_rows_match_dense_untouched_frozen() {
        let (sg, flat, pc) = sparse_fixture(&[0, 4], 1.5);
        let mut dense = Adam::new(pc, 0.05, 0.9, 0.999, 1e-8);
        let mut lazy = dense.clone();
        let mut p_dense: Vec<f32> = (0..pc).map(|i| 1.0 + i as f32 * 0.25).collect();
        let mut p_lazy = p_dense.clone();
        let before = p_lazy.clone();
        dense.step(&mut p_dense, &flat);
        lazy.step_lazy(&mut p_lazy, &sg);
        assert_eq!(lazy.steps_taken(), 1);
        // Touched rows 0 and 4 (flat indices 0,1,8,9) and tail (10..13)
        // agree bit-for-bit; first step from zero moments is identical.
        for i in [0usize, 1, 8, 9, 10, 11, 12] {
            assert_eq!(p_dense[i], p_lazy[i], "index {i} diverged");
        }
        // Untouched rows are frozen under lazy Adam (dense also leaves
        // them unchanged on step 1 since m = v = 0 for a zero gradient).
        for i in [2usize, 3, 4, 5, 6, 7] {
            assert_eq!(p_lazy[i], before[i], "untouched index {i} moved");
        }
    }

    /// After warming the moments, dense Adam keeps updating untouched
    /// rows (momentum decay) while lazy Adam freezes them — the exact
    /// documented divergence.
    #[test]
    fn lazy_adam_diverges_only_where_documented() {
        let (sg1, flat1, pc) = sparse_fixture(&[2], 1.0);
        let (sg2, flat2, _) = sparse_fixture(&[4], -2.0);
        let mut dense = Adam::new(pc, 0.05, 0.9, 0.999, 1e-8);
        let mut lazy = dense.clone();
        let mut p_dense = vec![1.0f32; pc];
        let mut p_lazy = vec![1.0f32; pc];
        dense.step(&mut p_dense, &flat1);
        lazy.step_lazy(&mut p_lazy, &sg1);
        dense.step(&mut p_dense, &flat2);
        lazy.step_lazy(&mut p_lazy, &sg2);
        // Step 2 touched row 4 only; dense still moved row 2 via its
        // decayed momentum, lazy did not.
        assert_ne!(p_dense[4], p_lazy[4], "dense momentum should move row 2 again");
        // Tail saw identical nonzero gradients both steps: identical.
        for i in 10..13 {
            assert_eq!(p_dense[i], p_lazy[i], "tail index {i} diverged");
        }
    }

    /// 4 entity rows × 2 dims at offset 0, a 2-float dense middle, then
    /// 3 relation rows × 2 dims at offset 10.
    fn two_seg_fixture(
        ent_touched: &[u32],
        rel_touched: &[i32],
        salt: f32,
    ) -> (SparseGrad, Vec<f32>, usize) {
        let ent = EmbeddingSegment { offset: 0, rows: 4, dim: 2 };
        let rel = EmbeddingSegment { offset: 10, rows: 3, dim: 2 };
        let pc = 16;
        let mut flat = vec![0.0f32; pc];
        for &r in ent_touched {
            flat[r as usize * 2] = salt + r as f32;
            flat[r as usize * 2 + 1] = -salt * 0.5;
        }
        for &r in rel_touched {
            flat[10 + r as usize * 2] = salt * 0.75 - r as f32;
            flat[10 + r as usize * 2 + 1] = salt * 0.25;
        }
        flat[8] = salt;
        flat[9] = -salt;
        let mut sg = SparseGrad::with_relations(Some(ent), Some(rel), pc);
        sg.accumulate_with_rels(ent_touched, rel_touched, &flat);
        (sg, flat, pc)
    }

    /// With a relation segment, sparse SGD must still be bit-identical
    /// to dense SGD, and lazy Adam must update touched relation rows.
    #[test]
    fn relation_segment_flows_through_both_sparse_steps() {
        let (sg, flat, pc) = two_seg_fixture(&[0, 2], &[1, 2, 1], 1.25);
        let sgd = Sgd { lr: 0.2 };
        let mut p_dense: Vec<f32> = (0..pc).map(|i| i as f32 * 0.5 - 1.0).collect();
        let mut p_sparse = p_dense.clone();
        sgd.step(&mut p_dense, &flat);
        sgd.step_sparse(&mut p_sparse, &sg);
        assert_eq!(p_dense, p_sparse);

        let mut dense = Adam::new(pc, 0.05, 0.9, 0.999, 1e-8);
        let mut lazy = dense.clone();
        let mut p_dense: Vec<f32> = (0..pc).map(|i| 1.0 + i as f32 * 0.25).collect();
        let mut p_lazy = p_dense.clone();
        let before = p_lazy.clone();
        dense.step(&mut p_dense, &flat);
        lazy.step_lazy(&mut p_lazy, &sg);
        // Touched ent rows 0,2 (flat 0,1,4,5), rel rows 1,2 (flat
        // 12..16), and the dense middle (8,9) agree bit-for-bit on the
        // first step from zero moments.
        for i in [0usize, 1, 4, 5, 8, 9, 12, 13, 14, 15] {
            assert_eq!(p_dense[i], p_lazy[i], "index {i} diverged");
        }
        // Untouched ent rows 1,3 and rel row 0 stay frozen under lazy.
        for i in [2usize, 3, 6, 7, 10, 11] {
            assert_eq!(p_lazy[i], before[i], "untouched index {i} moved");
        }
    }

    /// Lazy Adam still optimizes: quadratic convergence through the
    /// sparse path.
    #[test]
    fn lazy_adam_minimizes_quadratic_on_touched_row() {
        let seg = EmbeddingSegment { offset: 0, rows: 1, dim: 1 };
        let mut adam = Adam::new(1, 0.1, 0.9, 0.999, 1e-8);
        let mut params = vec![3.0f32];
        for _ in 0..200 {
            let mut sg = SparseGrad::new(Some(seg), 1);
            sg.accumulate(&[0], &[2.0 * params[0]]);
            adam.step_lazy(&mut params, &sg);
        }
        assert!(params[0].abs() < 0.05, "did not converge: {}", params[0]);
    }
}

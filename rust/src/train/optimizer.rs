//! Adam optimizer over the flat parameter vector.
//!
//! The optimizer lives in Rust (L3 owns parameter state; XLA computes
//! gradients), runs once per synchronous step on the globally-averaged
//! gradient, and is fully deterministic. Standard Adam (Kingma & Ba)
//! with bias correction.

#[derive(Clone, Debug)]
pub struct Adam {
    pub lr: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    m: Vec<f32>,
    v: Vec<f32>,
    t: u64,
}

impl Adam {
    pub fn new(param_count: usize, lr: f64, beta1: f64, beta2: f64, eps: f64) -> Self {
        Adam {
            lr: lr as f32,
            beta1: beta1 as f32,
            beta2: beta2 as f32,
            eps: eps as f32,
            m: vec![0.0; param_count],
            v: vec![0.0; param_count],
            t: 0,
        }
    }

    pub fn from_config(param_count: usize, cfg: &crate::config::TrainConfig) -> Self {
        Self::new(param_count, cfg.lr, cfg.adam_beta1, cfg.adam_beta2, cfg.adam_eps)
    }

    /// One update: `params -= lr * m̂ / (sqrt(v̂) + eps)`.
    pub fn step(&mut self, params: &mut [f32], grads: &[f32]) {
        assert_eq!(params.len(), self.m.len());
        assert_eq!(grads.len(), self.m.len());
        self.t += 1;
        let bc1 = 1.0 - self.beta1.powi(self.t as i32);
        let bc2 = 1.0 - self.beta2.powi(self.t as i32);
        // Fold the bias corrections into a single scalar multiplier so the
        // inner loop is 2 fma + 1 sqrt per element.
        let lr_t = self.lr * bc2.sqrt() / bc1;
        let (b1, b2, eps) = (self.beta1, self.beta2, self.eps);
        for i in 0..params.len() {
            let g = grads[i];
            let m = b1 * self.m[i] + (1.0 - b1) * g;
            let v = b2 * self.v[i] + (1.0 - b2) * g * g;
            self.m[i] = m;
            self.v[i] = v;
            params[i] -= lr_t * m / (v.sqrt() + eps);
        }
    }

    pub fn steps_taken(&self) -> u64 {
        self.t
    }

    /// Reset moments (used when reusing a trainer across experiments).
    pub fn reset(&mut self) {
        self.m.fill(0.0);
        self.v.fill(0.0);
        self.t = 0;
    }

    /// Raw state access for checkpointing.
    pub fn state(&self) -> (&[f32], &[f32], u64) {
        (&self.m, &self.v, self.t)
    }

    pub fn restore(&mut self, m: Vec<f32>, v: Vec<f32>, t: u64) {
        assert_eq!(m.len(), self.m.len());
        assert_eq!(v.len(), self.v.len());
        self.m = m;
        self.v = v;
        self.t = t;
    }
}

/// Plain SGD — the ablation/debug optimizer.
#[derive(Clone, Debug)]
pub struct Sgd {
    pub lr: f32,
}

impl Sgd {
    pub fn step(&self, params: &mut [f32], grads: &[f32]) {
        for (p, g) in params.iter_mut().zip(grads) {
            *p -= self.lr * g;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Adam on f(x) = x² must converge toward 0 from any start.
    #[test]
    fn adam_minimizes_quadratic() {
        let mut adam = Adam::new(1, 0.1, 0.9, 0.999, 1e-8);
        let mut params = vec![3.0f32];
        for _ in 0..200 {
            let g = vec![2.0 * params[0]];
            adam.step(&mut params, &g);
        }
        assert!(params[0].abs() < 0.05, "did not converge: {}", params[0]);
    }

    #[test]
    fn first_step_magnitude_is_lr() {
        // With bias correction, the first Adam step ≈ lr * sign(g).
        let mut adam = Adam::new(3, 0.01, 0.9, 0.999, 1e-8);
        let mut params = vec![1.0f32, -2.0, 0.5];
        adam.step(&mut params, &[0.3, -0.7, 100.0]);
        assert!((params[0] - (1.0 - 0.01)).abs() < 1e-4);
        assert!((params[1] - (-2.0 + 0.01)).abs() < 1e-4);
        assert!((params[2] - (0.5 - 0.01)).abs() < 1e-4);
    }

    #[test]
    fn deterministic_across_instances() {
        let mut a = Adam::new(4, 0.05, 0.9, 0.999, 1e-8);
        let mut b = Adam::new(4, 0.05, 0.9, 0.999, 1e-8);
        let mut pa = vec![1.0, 2.0, 3.0, 4.0];
        let mut pb = pa.clone();
        for i in 0..10 {
            let g: Vec<f32> = (0..4).map(|j| ((i + j) as f32).sin()).collect();
            a.step(&mut pa, &g);
            b.step(&mut pb, &g);
        }
        assert_eq!(pa, pb);
    }

    #[test]
    fn reset_and_restore_roundtrip() {
        let mut adam = Adam::new(2, 0.1, 0.9, 0.999, 1e-8);
        let mut p = vec![1.0f32, 1.0];
        adam.step(&mut p, &[0.1, 0.2]);
        let (m, v, t) = adam.state();
        let (m, v) = (m.to_vec(), v.to_vec());
        assert_eq!(t, 1);
        adam.reset();
        assert_eq!(adam.steps_taken(), 0);
        adam.restore(m, v, t);
        assert_eq!(adam.steps_taken(), 1);
    }

    #[test]
    fn sgd_step_is_linear() {
        let sgd = Sgd { lr: 0.5 };
        let mut p = vec![1.0f32, 2.0];
        sgd.step(&mut p, &[1.0, -2.0]);
        assert_eq!(p, vec![0.5, 3.0]);
    }
}

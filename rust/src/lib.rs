//! # kgscale
//!
//! Reproduction of *"Scaling Knowledge Graph Embedding Models"* (Sheikh
//! et al., 2022): distributed data-parallel training of GNN-based
//! knowledge-graph embedding models (RGCN encoder + DistMult decoder) for
//! link prediction, built on self-sufficient vertex-cut partitions,
//! constraint-based negative sampling, and edge mini-batch training.
//!
//! Architecture (see DESIGN.md): this Rust crate is the Layer-3
//! coordinator — partitioning, sampling, batching, the data-parallel
//! trainer with ring AllReduce, evaluation, and all experiment harnesses.
//! The numerical model (Layer 2: JAX RGCN/DistMult; Layer 1: Pallas
//! kernels) is AOT-compiled by `python/compile/aot.py` into
//! `artifacts/*.hlo.txt`, which `runtime` loads and executes through the
//! PJRT C API. Python never runs on the training path.

pub mod cli;
pub mod config;
pub mod eval;
pub mod experiments;
pub mod graph;
pub mod metrics;
pub mod model;
pub mod partition;
pub mod report;
pub mod runtime;
pub mod sampler;
pub mod testing;
pub mod train;
pub mod util;

//! Training metrics: the per-batch component timings the paper reports in
//! Figure 6(b) (`getComputeGraph`, `GNNmodel`, `loss+backward+step`) and
//! per-epoch records for Tables 3-4 and Figure 7.
//!
//! Component mapping note: our AOT artifact fuses forward, loss, and
//! backward into one `train_step` executable, so "GNNmodel" here measures
//! forward+loss+backward together and "sync+step" measures gradient
//! averaging (modeled AllReduce) plus the optimizer. EXPERIMENTS.md
//! carries the mapping caveat next to the Figure 6 reproduction.

use crate::util::stats::Welford;

/// Per-batch component accumulators (virtual-cluster seconds).
#[derive(Clone, Debug, Default)]
pub struct ComponentTimes {
    /// Compute-graph extraction (paper: getComputeGraph).
    pub get_compute_graph: Welford,
    /// train_step execution: forward + loss + backward.
    pub gnn_model: Welford,
    /// Gradient sync (modeled) + optimizer step (measured).
    pub sync_step: Welford,
    /// Wall seconds per step the coordinator spent blocked waiting for
    /// a prepared batch from the host pipeline (always 0.0 on the
    /// sequential `host_threads = 0` path).
    pub prefetch_stall: Welford,
}

impl ComponentTimes {
    pub fn new() -> Self {
        Self::default()
    }
}

/// One epoch of one training run.
#[derive(Clone, Debug, Default)]
pub struct EpochRecord {
    pub epoch: usize,
    /// Mean BCE loss over the epoch's triples.
    pub mean_loss: f64,
    /// Simulated P-trainer cluster time (see train::netsim).
    pub virtual_secs: f64,
    /// Actual wall time on this machine (serial execution of all workers).
    pub wall_secs: f64,
    pub num_steps: usize,
    /// Mean per-batch component times, virtual seconds.
    pub avg_compute_graph: f64,
    pub avg_gnn_model: f64,
    pub avg_sync_step: f64,
    /// Simulated remote fetches charged this epoch (global-negative
    /// ablation; 0 under constraint-based sampling).
    pub remote_fetches: usize,
    /// Mean embedding rows touched per synchronous step (union across
    /// workers) under the sparse gradient modes; 0.0 in dense mode,
    /// which does not track touched rows.
    pub avg_touched_rows: f64,
    /// Mean gradient bytes a worker puts on the wire per step: the
    /// sparse transfer size (touched entity + relation rows + dense
    /// remainder) under `grad_sync = "sparse"`, else the dense
    /// `param_count * 4`.
    pub avg_sync_bytes: f64,
    /// Total wall seconds this epoch the coordinator spent blocked
    /// waiting on the host prep pipeline (0.0 on the sequential path).
    pub prefetch_stall_secs: f64,
    /// Share of host prep work hidden behind coordinator execution:
    /// `(prep_busy - stall) / prep_busy`, clamped to [0, 1]. 0.0 when
    /// the sequential path ran (no concurrent prep to hide).
    pub overlap_efficiency: f64,
    /// Wall seconds of the periodic evaluation that followed this epoch
    /// (0.0 when no eval ran after this epoch).
    pub eval_wall_secs: f64,
    /// Seconds that eval's coordinator spent blocked waiting on the
    /// rank pool (0.0 on the sequential `eval.host_threads = 0` path,
    /// and when no eval ran).
    pub eval_rank_stall_secs: f64,
    /// That eval's rank-work overlap efficiency,
    /// `(rank_busy - stall) / rank_busy` clamped to [0, 1]; 0.0 on the
    /// sequential path and when no eval ran.
    pub eval_overlap_efficiency: f64,
    /// Crash-recovery events this epoch (worker restored from the last
    /// checkpoint after a `train::faults` crash). 0 with faults off.
    pub fault_recoveries: usize,
    /// Synchronous steps deterministically re-executed during recovery
    /// (from the restored checkpoint boundary up to the crash step).
    pub replayed_steps: usize,
    /// Virtual seconds charged for recovery: failure detection +
    /// checkpoint read + state transfer + deterministic replay.
    pub recovery_secs: f64,
    /// Extra virtual compute seconds injected by straggler windows (sum
    /// over workers of inflated minus raw step compute).
    pub straggler_secs: f64,
    /// Wall seconds spent writing the periodic checkpoint(s) at this
    /// epoch's boundary (also charged to the virtual clock).
    pub checkpoint_write_secs: f64,
}

/// Timing breakdown of one evaluation pass (wall seconds).
#[derive(Clone, Copy, Debug, Default)]
pub struct EvalStats {
    /// End-to-end wall time: encode + score + rank + fold.
    pub wall_secs: f64,
    /// Full-graph encode artifact execution (inputs come from the
    /// cached `EncodeInputs`, so this is pure XLA time after warmup).
    pub encode_secs: f64,
    /// Score artifact execution summed over chunks.
    pub score_secs: f64,
    /// Host rank work: coordinator seconds on the sequential path, or
    /// summed pool-thread busy seconds on the overlapped path.
    pub rank_secs: f64,
    /// Coordinator seconds blocked waiting for rank stripes (0.0 on the
    /// sequential path).
    pub rank_stall_secs: f64,
    /// `(rank_secs - rank_stall_secs) / rank_secs` clamped to [0, 1] on
    /// the overlapped path; 0.0 sequentially (nothing ran concurrently).
    pub overlap_efficiency: f64,
    /// Score chunks executed.
    pub num_chunks: usize,
}

/// Full run history plus evaluation checkpoints (Figure 7's series).
#[derive(Clone, Debug, Default)]
pub struct RunHistory {
    pub epochs: Vec<EpochRecord>,
    /// (virtual time at eval, epoch, validation MRR)
    pub eval_points: Vec<(f64, usize, f64)>,
    /// Timing breakdown of each eval point, parallel to `eval_points`
    /// (empty for callers that record MRR only).
    pub eval_stats: Vec<EvalStats>,
}

impl RunHistory {
    pub fn total_virtual_secs(&self) -> f64 {
        self.epochs.iter().map(|e| e.virtual_secs).sum()
    }

    pub fn total_wall_secs(&self) -> f64 {
        self.epochs.iter().map(|e| e.wall_secs).sum()
    }

    pub fn mean_epoch_virtual_secs(&self) -> f64 {
        if self.epochs.is_empty() {
            0.0
        } else {
            self.total_virtual_secs() / self.epochs.len() as f64
        }
    }

    pub fn final_loss(&self) -> f64 {
        self.epochs.last().map(|e| e.mean_loss).unwrap_or(f64::NAN)
    }

    pub fn best_eval_mrr(&self) -> f64 {
        self.eval_points.iter().map(|&(_, _, m)| m).fold(0.0, f64::max)
    }

    /// Crash-recovery events across the run (0 with faults off).
    pub fn total_recoveries(&self) -> usize {
        self.epochs.iter().map(|e| e.fault_recoveries).sum()
    }

    /// Steps deterministically re-executed by recoveries across the run.
    pub fn total_replayed_steps(&self) -> usize {
        self.epochs.iter().map(|e| e.replayed_steps).sum()
    }

    /// Virtual seconds spent in recovery across the run.
    pub fn total_recovery_secs(&self) -> f64 {
        self.epochs.iter().map(|e| e.recovery_secs).sum()
    }

    /// Wall seconds spent writing checkpoints across the run.
    pub fn total_checkpoint_write_secs(&self) -> f64 {
        self.epochs.iter().map(|e| e.checkpoint_write_secs).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn history_aggregates() {
        let mut h = RunHistory::default();
        for e in 0..3 {
            h.epochs.push(EpochRecord {
                epoch: e,
                mean_loss: 1.0 / (e + 1) as f64,
                virtual_secs: 2.0,
                wall_secs: 4.0,
                num_steps: 10,
                avg_compute_graph: 0.1,
                avg_gnn_model: 0.05,
                avg_sync_step: 0.01,
                remote_fetches: 0,
                avg_touched_rows: 128.0,
                avg_sync_bytes: 128.0 * 16.0 * 4.0,
                prefetch_stall_secs: 0.25,
                overlap_efficiency: 0.9,
                eval_wall_secs: 0.0,
                eval_rank_stall_secs: 0.0,
                eval_overlap_efficiency: 0.0,
                fault_recoveries: 1,
                replayed_steps: 5,
                recovery_secs: 0.5,
                straggler_secs: 0.125,
                checkpoint_write_secs: 0.25,
            });
        }
        h.eval_points.push((2.0, 0, 0.1));
        h.eval_points.push((4.0, 1, 0.3));
        h.eval_points.push((6.0, 2, 0.25));
        assert!((h.total_virtual_secs() - 6.0).abs() < 1e-12);
        assert!((h.mean_epoch_virtual_secs() - 2.0).abs() < 1e-12);
        assert!((h.final_loss() - 1.0 / 3.0).abs() < 1e-12);
        assert!((h.best_eval_mrr() - 0.3).abs() < 1e-12);
        assert!((h.total_wall_secs() - 12.0).abs() < 1e-12);
        assert_eq!(h.total_recoveries(), 3);
        assert_eq!(h.total_replayed_steps(), 15);
        assert!((h.total_recovery_secs() - 1.5).abs() < 1e-12);
        assert!((h.total_checkpoint_write_secs() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn empty_history_is_safe() {
        let h = RunHistory::default();
        assert_eq!(h.mean_epoch_virtual_secs(), 0.0);
        assert!(h.final_loss().is_nan());
        assert_eq!(h.best_eval_mrr(), 0.0);
    }
}

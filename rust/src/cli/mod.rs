//! Hand-rolled CLI argument parsing (no `clap` in the offline build).
//!
//! Grammar: `kgscale <command> [--key value]... [--flag]...`
//! Unknown keys are an error (catching typos beats silently ignoring).

use anyhow::{bail, Result};
use std::collections::BTreeMap;

#[derive(Debug, Default)]
pub struct Args {
    pub command: String,
    pub positional: Vec<String>,
    options: BTreeMap<String, String>,
    flags: Vec<String>,
    consumed: std::cell::RefCell<Vec<String>>,
}

impl Args {
    pub fn parse(argv: &[String]) -> Result<Args> {
        let mut args = Args::default();
        let mut it = argv.iter().peekable();
        if let Some(cmd) = it.next() {
            args.command = cmd.clone();
        }
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                // flag if next is absent or another option
                match it.peek() {
                    Some(v) if !v.starts_with("--") => {
                        let v = it.next().unwrap();
                        if args.options.insert(key.to_string(), v.clone()).is_some() {
                            bail!("duplicate option --{key}");
                        }
                    }
                    _ => args.flags.push(key.to_string()),
                }
            } else {
                args.positional.push(a.clone());
            }
        }
        Ok(args)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.consumed.borrow_mut().push(key.to_string());
        self.options.get(key).map(String::as_str)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow::anyhow!("--{key} wants an integer, got {v:?}")),
        }
    }

    pub fn get_usize_list(&self, key: &str, default: &[usize]) -> Result<Vec<usize>> {
        match self.get(key) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|p| {
                    p.trim()
                        .parse()
                        .map_err(|_| anyhow::anyhow!("--{key}: bad integer {p:?}"))
                })
                .collect(),
        }
    }

    pub fn flag(&self, key: &str) -> bool {
        self.consumed.borrow_mut().push(key.to_string());
        self.flags.iter().any(|f| f == key)
    }

    /// Error on any option/flag never consumed by the command.
    pub fn finish(&self) -> Result<()> {
        let consumed = self.consumed.borrow();
        for k in self.options.keys() {
            if !consumed.contains(k) {
                bail!("unknown option --{k} for command {:?}", self.command);
            }
        }
        for f in &self.flags {
            if !consumed.contains(f) {
                bail!("unknown flag --{f} for command {:?}", self.command);
            }
        }
        Ok(())
    }
}

pub const USAGE: &str = "\
kgscale — distributed GNN knowledge-graph embedding training
          (reproduction of Sheikh et al., 'Scaling Knowledge Graph
           Embedding Models', 2022)

USAGE: kgscale <command> [options]

COMMANDS
  info                         platform + artifact inventory
  generate  --config C [--out DIR]
                               generate the synthetic dataset
  plan      --config C [--trainers 1,2,4,8] [--out plan.json]
                               measure AOT bucket sizes for aot.py
  partition --config C [--partitions 4] [--strategy hdrf|dbh|metis_like|random]
            [--build-threads N] [--cache-dir DIR]
                               partition + expand, print Table-2 stats
                               plus build breakdown (N=0: sequential;
                               DIR caches builds keyed by graph+config+seed)
  train     --config C [--trainers P] [--epochs N] [--eval-every K]
            [--resume DIR] [--checkpoint-dir DIR] [--checkpoint-every K]
                               train and report loss/MRR; --resume
                               continues from the newest checkpoint in
                               DIR, --checkpoint-dir/--checkpoint-every
                               override the [train] checkpoint keys
  experiment <table1|table2|table3|table4|table5|fig2|fig6|fig7|all>
            --config C [--trainers 1,2,4,8] [--epochs N] ...
                               regenerate a paper table/figure
  help                         this text

Options shared by training commands:
  --config <path.toml>   experiment config (defaults to built-in tiny tier)
  --artifacts <dir>      artifact root (default: from config)
";

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(String::from).collect()
    }

    #[test]
    fn parses_options_flags_positionals() {
        let a = Args::parse(&argv("experiment table3 --trainers 1,2,4 --force --epochs 5")).unwrap();
        assert_eq!(a.command, "experiment");
        assert_eq!(a.positional, vec!["table3"]);
        assert_eq!(a.get("trainers"), Some("1,2,4"));
        assert_eq!(a.get_usize("epochs", 0).unwrap(), 5);
        assert!(a.flag("force"));
        a.finish().unwrap();
    }

    #[test]
    fn unknown_option_rejected_on_finish() {
        let a = Args::parse(&argv("train --bogus 3")).unwrap();
        assert!(a.finish().is_err());
    }

    #[test]
    fn usize_list_parsing() {
        let a = Args::parse(&argv("x --trainers 1,2,8")).unwrap();
        assert_eq!(a.get_usize_list("trainers", &[]).unwrap(), vec![1, 2, 8]);
        let b = Args::parse(&argv("x")).unwrap();
        assert_eq!(b.get_usize_list("trainers", &[1, 4]).unwrap(), vec![1, 4]);
        let c = Args::parse(&argv("x --trainers 1,zz")).unwrap();
        assert!(c.get_usize_list("trainers", &[]).is_err());
    }

    #[test]
    fn duplicate_option_rejected() {
        assert!(Args::parse(&argv("x --a 1 --a 2")).is_err());
    }
}

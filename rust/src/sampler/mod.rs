//! Training-data pipeline per partition (paper §3.3): constraint-based
//! negative sampling, edge mini-batching, and compute-graph extraction.
//!
//! [`PartContext`] freezes one partition into local-id form (dense local
//! vertex numbering, local CSR over all message edges). Per epoch, the
//! [`negative`] sampler corrupts each core edge into `s` negatives drawn
//! from the partition's core vertices (the paper's locally-closed-world
//! constraint), [`batch`] shuffles and chunks positives+negatives into
//! edge mini-batches, and [`compute_graph`] extracts the n-hop
//! message-passing closure of each batch — the paper's
//! `getComputeGraph`, its measured per-batch hot spot.

pub mod batch;
pub mod compute_graph;
pub mod negative;

use crate::graph::{Csr, Triple};
use crate::partition::Partition;

/// A training example in partition-local vertex ids.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TrainTriple {
    pub s: u32,
    pub r: u32,
    pub t: u32,
    /// 1.0 positive, 0.0 negative (Eq. 3's y).
    pub label: f32,
}

/// A partition frozen into local-id form for training.
#[derive(Clone, Debug)]
pub struct PartContext {
    pub part_id: usize,
    /// Global vertex id of each local id (sorted — same order as
    /// `Partition::vertices`).
    pub global_nodes: Vec<u32>,
    /// All message-passing edges (core + support) in local ids.
    pub edges: Vec<Triple>,
    /// CSR over `edges` (local vertex space).
    pub csr: Csr,
    /// Core (positive) edges in local ids.
    pub core_edges: Vec<Triple>,
    /// Local ids of core vertices — the constraint-based negative
    /// sampler's domain (paper §3.3.1).
    pub core_vertices: Vec<u32>,
}

impl PartContext {
    pub fn new(part: &Partition) -> Self {
        let global_nodes = part.vertices.clone();
        let to_local = |g: u32| -> u32 {
            part.local_of(g).expect("partition edge endpoint missing from vertex set")
        };
        let localize = |e: &Triple| Triple::new(to_local(e.s), e.r, to_local(e.t));
        let core_edges: Vec<Triple> = part.core_edges.iter().map(localize).collect();
        let mut edges: Vec<Triple> = core_edges.clone();
        edges.extend(part.support_edges.iter().map(localize));
        let csr = Csr::build(global_nodes.len(), &edges);
        let core_vertices: Vec<u32> = part
            .vertices
            .iter()
            .zip(&part.roles)
            .enumerate()
            .filter(|(_, (_, role))| !matches!(role, crate::partition::VertexRole::Support))
            .map(|(local, _)| local as u32)
            .collect();
        PartContext { part_id: part.id, global_nodes, edges, csr, core_edges, core_vertices }
    }

    pub fn num_local_vertices(&self) -> usize {
        self.global_nodes.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ExperimentConfig, PartitionConfig, PartitionStrategy};
    use crate::graph::generator;
    use crate::partition;

    pub(crate) fn make_contexts(p: usize) -> (crate::graph::KnowledgeGraph, Vec<PartContext>) {
        let g = generator::generate(&ExperimentConfig::tiny().dataset);
        let cfg = PartitionConfig {
            strategy: PartitionStrategy::Hdrf,
            num_partitions: p,
            ..Default::default()
        };
        let parts = partition::partition_graph(&g, &cfg, 5);
        let ctxs = parts.iter().map(PartContext::new).collect();
        (g, ctxs)
    }

    #[test]
    fn localization_roundtrips_to_global() {
        let (g, ctxs) = make_contexts(3);
        let mut seen_core = 0usize;
        for ctx in &ctxs {
            for e in &ctx.core_edges {
                let gs = ctx.global_nodes[e.s as usize];
                let gt = ctx.global_nodes[e.t as usize];
                assert!(
                    g.train.contains(&Triple::new(gs, e.r, gt)),
                    "core edge does not map back to a train triple"
                );
                seen_core += 1;
            }
        }
        assert_eq!(seen_core, g.train.len());
    }

    #[test]
    fn edge_ids_are_local_and_in_range() {
        let (_, ctxs) = make_contexts(3);
        for ctx in &ctxs {
            let n = ctx.num_local_vertices() as u32;
            for e in &ctx.edges {
                assert!(e.s < n && e.t < n);
            }
            assert!(ctx.core_vertices.iter().all(|&v| v < n));
            assert!(!ctx.core_vertices.is_empty());
        }
    }

    #[test]
    fn csr_covers_all_partition_edges() {
        let (_, ctxs) = make_contexts(2);
        for ctx in &ctxs {
            let total: usize =
                (0..ctx.num_local_vertices() as u32).map(|v| ctx.csr.out_degree(v)).sum();
            assert_eq!(total, ctx.edges.len());
        }
    }
}

//! Edge mini-batching (paper §3.3.2, Algorithm 1 lines 3-4).
//!
//! Per epoch: the negative sampler produces `s` negatives per core edge;
//! positives and negatives are concatenated, shuffled, and chunked into
//! batches of `batch_triples` examples. `batch_edges = 0` in the config
//! means full-batch (the paper's FB15k-237 setting); otherwise the
//! configured positive-edge budget is scaled by (1 + s) to give the
//! triple count per batch, matching the paper's "batch of b edges
//! (positive and negative)".

use super::{PartContext, TrainTriple};
use crate::util::rng::Rng;

/// One epoch's worth of shuffled training triples, chunked into batches.
pub struct EpochBatches {
    triples: Vec<TrainTriple>,
    batch_size: usize,
}

impl EpochBatches {
    /// Build the epoch plan for one partition.
    ///
    /// `batch_pos_edges == 0` ⇒ single full batch.
    pub fn build(
        ctx: &PartContext,
        negatives: Vec<TrainTriple>,
        batch_pos_edges: usize,
        rng: &mut Rng,
    ) -> EpochBatches {
        let mut triples: Vec<TrainTriple> = Vec::with_capacity(ctx.core_edges.len() + negatives.len());
        triples.extend(ctx.core_edges.iter().map(|e| TrainTriple {
            s: e.s,
            r: e.r,
            t: e.t,
            label: 1.0,
        }));
        let neg_ratio = if ctx.core_edges.is_empty() {
            1
        } else {
            (negatives.len() / ctx.core_edges.len()).max(1)
        };
        triples.extend(negatives);
        rng.shuffle(&mut triples);
        let batch_size = if batch_pos_edges == 0 {
            triples.len().max(1)
        } else {
            (batch_pos_edges * (1 + neg_ratio)).max(1)
        };
        EpochBatches { triples, batch_size }
    }

    pub fn num_batches(&self) -> usize {
        self.triples.len().div_ceil(self.batch_size)
    }

    pub fn total_triples(&self) -> usize {
        self.triples.len()
    }

    pub fn batch_size(&self) -> usize {
        self.batch_size
    }

    pub fn iter(&self) -> impl Iterator<Item = &[TrainTriple]> {
        self.triples.chunks(self.batch_size)
    }

    /// The `i`-th batch — the same chunk [`iter`](Self::iter) yields at
    /// position `i` — without cloning storage. The trainer keeps the
    /// `EpochBatches` alive for the whole epoch and indexes chunks
    /// directly per step (no per-epoch triple copies).
    pub fn batch(&self, i: usize) -> Option<&[TrainTriple]> {
        let start = i * self.batch_size;
        if start >= self.triples.len() {
            return None;
        }
        let end = (start + self.batch_size).min(self.triples.len());
        Some(&self.triples[start..end])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sampler::negative::{NegativeSampler, Scope};
    use crate::sampler::tests::make_contexts;

    fn epoch(p: usize, batch_pos: usize, seed: u64) -> (EpochBatches, usize) {
        let (g, ctxs) = make_contexts(p);
        let ctx = &ctxs[0];
        let sampler = NegativeSampler::new(ctx, Scope::LocalCore, g.num_entities);
        let mut rng = Rng::seeded(seed);
        let (negs, _) = sampler.sample_epoch(ctx, 1, &mut rng);
        let n_core = ctx.core_edges.len();
        (EpochBatches::build(ctx, negs, batch_pos, &mut rng), n_core)
    }

    #[test]
    fn full_batch_is_single_chunk() {
        let (ep, n_core) = epoch(2, 0, 1);
        assert_eq!(ep.num_batches(), 1);
        assert_eq!(ep.total_triples(), 2 * n_core); // 1 negative per positive
        assert_eq!(ep.iter().next().unwrap().len(), ep.total_triples());
    }

    #[test]
    fn minibatches_cover_everything_once() {
        let (ep, _) = epoch(2, 64, 2);
        let total: usize = ep.iter().map(|b| b.len()).sum();
        assert_eq!(total, ep.total_triples());
        assert!(ep.num_batches() > 1);
        // batch size is pos_edges * (1 + s) = 64 * 2
        assert_eq!(ep.batch_size(), 128);
        for b in ep.iter().take(ep.num_batches() - 1) {
            assert_eq!(b.len(), 128);
        }
    }

    #[test]
    fn labels_balanced_overall() {
        let (ep, n_core) = epoch(2, 0, 3);
        let pos = ep.iter().flatten().filter(|t| t.label == 1.0).count();
        let neg = ep.iter().flatten().filter(|t| t.label == 0.0).count();
        assert_eq!(pos, n_core);
        assert_eq!(neg, n_core);
    }

    #[test]
    fn batch_accessor_matches_iter() {
        let (ep, _) = epoch(2, 64, 2);
        assert!(ep.num_batches() > 1);
        for (i, chunk) in ep.iter().enumerate() {
            assert_eq!(ep.batch(i), Some(chunk));
        }
        assert_eq!(ep.batch(ep.num_batches()), None);
        assert_eq!(ep.batch(ep.num_batches() + 7), None);
    }

    #[test]
    fn shuffling_differs_by_seed_but_is_deterministic() {
        let (a, _) = epoch(2, 32, 4);
        let (b, _) = epoch(2, 32, 4);
        let (c, _) = epoch(2, 32, 5);
        let av: Vec<_> = a.iter().flatten().collect();
        let bv: Vec<_> = b.iter().flatten().collect();
        let cv: Vec<_> = c.iter().flatten().collect();
        assert_eq!(av, bv);
        assert_ne!(av, cv);
    }
}

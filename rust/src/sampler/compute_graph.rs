//! Compute-graph extraction — the paper's `getComputeGraph` (§3.3.2/3.3.3
//! and the dominant per-batch cost in Figure 6b).
//!
//! Given an edge mini-batch, extract the n-hop message-passing closure:
//! every vertex whose hidden state feeds a batch endpoint's embedding and
//! every directed message edge between them. The result uses a dense
//! *cg-local* id space so the HLO executable can gather/scatter with
//! small indices.
//!
//! Message-edge rule (mirrors `partition::expansion` and the L2 model,
//! which processes directed messages with inverse relations): for a
//! stored edge (u, r, v), the forward message u→v (relation r) is needed
//! iff dist(v) ≤ n-1, and the inverse message v→u (relation r+R) iff
//! dist(u) ≤ n-1.
//!
//! The builder is arena-style: all visit state is stamped (O(1) logical
//! reset), so per-batch extraction allocates only the output vectors.

use super::{PartContext, TrainTriple};


/// A batch's message-passing closure in dense cg-local ids.
#[derive(Clone, Debug, Default)]
pub struct ComputeGraph {
    /// cg-local -> global vertex id (embedding/feature gather key).
    pub nodes_global: Vec<u32>,
    /// cg-local -> partition-local vertex id.
    pub nodes_part: Vec<u32>,
    /// Directed message edges in cg-local ids; `rel` already includes the
    /// inverse-relation offset (+R) for reversed messages.
    pub src: Vec<i32>,
    pub dst: Vec<i32>,
    pub rel: Vec<i32>,
    /// Batch triples in cg-local ids, with labels.
    pub ts: Vec<i32>,
    pub tr: Vec<i32>,
    pub tt: Vec<i32>,
    pub labels: Vec<f32>,
}

impl ComputeGraph {
    pub fn num_nodes(&self) -> usize {
        self.nodes_global.len()
    }

    pub fn num_edges(&self) -> usize {
        self.src.len()
    }

    pub fn num_triples(&self) -> usize {
        self.ts.len()
    }
}

/// Reusable extractor over one partition.
pub struct ComputeGraphBuilder {
    stamp: u32,
    /// Visit stamps + assigned cg-local id per partition-local vertex.
    node_stamp: Vec<u32>,
    node_cg: Vec<u32>,
    node_dist: Vec<u32>,
    /// Emission stamps per partition edge and direction (fwd=bit0 via
    /// stamp equality in `edge_fwd`, inv in `edge_inv`).
    edge_fwd: Vec<u32>,
    edge_inv: Vec<u32>,
    /// BFS queue of partition-local vertex ids (reused).
    queue: Vec<u32>,
}

impl ComputeGraphBuilder {
    pub fn new(ctx: &PartContext) -> Self {
        ComputeGraphBuilder {
            stamp: 0,
            node_stamp: vec![0; ctx.num_local_vertices()],
            node_cg: vec![0; ctx.num_local_vertices()],
            node_dist: vec![0; ctx.num_local_vertices()],
            edge_fwd: vec![0; ctx.edges.len()],
            edge_inv: vec![0; ctx.edges.len()],
            queue: Vec::new(),
        }
    }

    /// Extract the `hops`-hop closure of `batch`. `num_relations` is the
    /// graph's base relation count R (inverse messages use r + R).
    pub fn build(
        &mut self,
        ctx: &PartContext,
        batch: &[TrainTriple],
        hops: usize,
        num_relations: usize,
    ) -> ComputeGraph {
        self.stamp += 1;
        let stamp = self.stamp;
        let mut cg = ComputeGraph::default();
        self.queue.clear();

        // Seed with batch endpoints (distance 0).
        let visit = |v: u32,
                         cg: &mut ComputeGraph,
                         queue: &mut Vec<u32>,
                         node_stamp: &mut [u32],
                         node_cg: &mut [u32],
                         node_dist: &mut [u32],
                         dist: u32|
         -> u32 {
            if node_stamp[v as usize] == stamp {
                return node_cg[v as usize];
            }
            node_stamp[v as usize] = stamp;
            node_dist[v as usize] = dist;
            let id = cg.nodes_part.len() as u32;
            node_cg[v as usize] = id;
            cg.nodes_part.push(v);
            cg.nodes_global.push(ctx.global_nodes[v as usize]);
            queue.push(v);
            id
        };

        for t in batch {
            let s_id = visit(
                t.s,
                &mut cg,
                &mut self.queue,
                &mut self.node_stamp,
                &mut self.node_cg,
                &mut self.node_dist,
                0,
            );
            let t_id = visit(
                t.t,
                &mut cg,
                &mut self.queue,
                &mut self.node_stamp,
                &mut self.node_cg,
                &mut self.node_dist,
                0,
            );
            cg.ts.push(s_id as i32);
            cg.tr.push(t.r as i32);
            cg.tt.push(t_id as i32);
            cg.labels.push(t.label);
        }

        // BFS: vertices at dist d <= hops-1 receive messages, so all
        // their incident edges emit a message toward them, and their
        // neighbors join at dist d+1.
        let mut head = 0usize;
        while head < self.queue.len() {
            let v = self.queue[head];
            head += 1;
            let d = self.node_dist[v as usize];
            if d as usize >= hops {
                continue;
            }
            let v_cg = self.node_cg[v as usize] as i32;
            // Incoming stored edges (u -> v): forward message u -> v.
            for &eid in ctx.csr.in_edges(v) {
                if self.edge_fwd[eid as usize] != stamp {
                    self.edge_fwd[eid as usize] = stamp;
                    let e = ctx.edges[eid as usize];
                    let u_cg = visit(
                        e.s,
                        &mut cg,
                        &mut self.queue,
                        &mut self.node_stamp,
                        &mut self.node_cg,
                        &mut self.node_dist,
                        d + 1,
                    );
                    cg.src.push(u_cg as i32);
                    cg.dst.push(v_cg);
                    cg.rel.push(e.r as i32);
                }
            }
            // Outgoing stored edges (v -> w): inverse message w -> v.
            for &eid in ctx.csr.out_edges(v) {
                if self.edge_inv[eid as usize] != stamp {
                    self.edge_inv[eid as usize] = stamp;
                    let e = ctx.edges[eid as usize];
                    let w_cg = visit(
                        e.t,
                        &mut cg,
                        &mut self.queue,
                        &mut self.node_stamp,
                        &mut self.node_cg,
                        &mut self.node_dist,
                        d + 1,
                    );
                    cg.src.push(w_cg as i32);
                    cg.dst.push(v_cg);
                    cg.rel.push((e.r + num_relations as u32) as i32);
                }
            }
        }
        cg
    }
}

/// Figure 2 helper: average number of vertices required to compute one
/// vertex embedding at `hops` hops, estimated over `sample` seed vertices
/// of the full (single-partition) context.
pub fn avg_closure_size(
    ctx: &PartContext,
    hops: usize,
    sample: usize,
    seed: u64,
) -> f64 {
    let mut builder = ComputeGraphBuilder::new(ctx);
    let mut rng = crate::util::rng::Rng::seeded(seed);
    let n = ctx.num_local_vertices();
    let take = sample.min(n);
    let mut total = 0usize;
    for _ in 0..take {
        let v = rng.below(n) as u32;
        let probe = [TrainTriple { s: v, r: 0, t: v, label: 1.0 }];
        let cg = builder.build(ctx, &probe, hops, 1);
        total += cg.num_nodes();
    }
    total as f64 / take as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sampler::tests::make_contexts;

    fn full_ctx() -> (crate::graph::KnowledgeGraph, PartContext) {
        let (g, mut ctxs) = make_contexts(1);
        (g, ctxs.remove(0))
    }

    #[test]
    fn closure_contains_all_batch_endpoints_first() {
        let (_, ctx) = full_ctx();
        let mut b = ComputeGraphBuilder::new(&ctx);
        let batch: Vec<TrainTriple> = ctx.core_edges[..8]
            .iter()
            .map(|e| TrainTriple { s: e.s, r: e.r, t: e.t, label: 1.0 })
            .collect();
        let cg = b.build(&ctx, &batch, 2, 8);
        assert_eq!(cg.num_triples(), 8);
        for i in 0..8 {
            // Triple endpoints must be valid cg ids mapping back to the
            // batch's partition-local vertices.
            let s_cg = cg.ts[i] as usize;
            let t_cg = cg.tt[i] as usize;
            assert_eq!(cg.nodes_part[s_cg], batch[i].s);
            assert_eq!(cg.nodes_part[t_cg], batch[i].t);
        }
    }

    #[test]
    fn edges_are_within_cg_and_rel_offset_applied() {
        let (g, ctx) = full_ctx();
        let r = g.num_relations;
        let mut b = ComputeGraphBuilder::new(&ctx);
        let batch: Vec<TrainTriple> = ctx.core_edges[..4]
            .iter()
            .map(|e| TrainTriple { s: e.s, r: e.r, t: e.t, label: 1.0 })
            .collect();
        let cg = b.build(&ctx, &batch, 2, r);
        let n = cg.num_nodes() as i32;
        assert!(cg.num_edges() > 0);
        let mut saw_fwd = false;
        let mut saw_inv = false;
        for i in 0..cg.num_edges() {
            assert!(cg.src[i] < n && cg.dst[i] < n);
            if (cg.rel[i] as usize) < r {
                saw_fwd = true;
            } else {
                assert!((cg.rel[i] as usize) < 2 * r);
                saw_inv = true;
            }
        }
        assert!(saw_fwd && saw_inv, "both directions should appear");
    }

    /// Every dist<=hops-1 vertex has its complete in+out neighborhood as
    /// messages — the correctness property message passing relies on.
    #[test]
    fn closure_is_message_complete() {
        let (g, ctx) = full_ctx();
        let r = g.num_relations;
        let hops = 2;
        let mut b = ComputeGraphBuilder::new(&ctx);
        let batch: Vec<TrainTriple> = ctx.core_edges[..3]
            .iter()
            .map(|e| TrainTriple { s: e.s, r: e.r, t: e.t, label: 1.0 })
            .collect();
        let cg = b.build(&ctx, &batch, hops, r);
        // Reconstruct dist via BFS over the partition from batch seeds.
        let n = ctx.num_local_vertices();
        let mut dist = vec![u32::MAX; n];
        let mut q: Vec<u32> = Vec::new();
        for t in &batch {
            for v in [t.s, t.t] {
                if dist[v as usize] == u32::MAX {
                    dist[v as usize] = 0;
                    q.push(v);
                }
            }
        }
        let mut head = 0;
        while head < q.len() {
            let v = q[head];
            head += 1;
            let d = dist[v as usize];
            if d as usize >= hops {
                continue;
            }
            for &eid in ctx.csr.in_edges(v).iter().chain(ctx.csr.out_edges(v)) {
                let e = ctx.edges[eid as usize];
                let w = if e.s == v { e.t } else { e.s };
                if dist[w as usize] == u32::MAX {
                    dist[w as usize] = d + 1;
                    q.push(w);
                }
            }
        }
        // Gather messages per cg-dst.
        use std::collections::HashSet;
        let mut msgs: HashSet<(i32, i32, i32)> = HashSet::new();
        for i in 0..cg.num_edges() {
            msgs.insert((cg.src[i], cg.dst[i], cg.rel[i]));
        }
        let cg_of: std::collections::HashMap<u32, i32> = cg
            .nodes_part
            .iter()
            .enumerate()
            .map(|(i, &p)| (p, i as i32))
            .collect();
        for v in 0..n as u32 {
            if dist[v as usize] as usize >= hops {
                continue;
            }
            if dist[v as usize] == u32::MAX {
                continue;
            }
            let v_cg = cg_of[&v];
            for &eid in ctx.csr.in_edges(v) {
                let e = ctx.edges[eid as usize];
                let u_cg = cg_of[&e.s];
                assert!(
                    msgs.contains(&(u_cg, v_cg, e.r as i32)),
                    "missing forward message for dist-{} vertex",
                    dist[v as usize]
                );
            }
            for &eid in ctx.csr.out_edges(v) {
                let e = ctx.edges[eid as usize];
                let w_cg = cg_of[&e.t];
                assert!(
                    msgs.contains(&(w_cg, v_cg, (e.r as usize + r) as i32)),
                    "missing inverse message"
                );
            }
        }
    }

    #[test]
    fn builder_is_reusable_and_deterministic() {
        let (g, ctx) = full_ctx();
        let mut b = ComputeGraphBuilder::new(&ctx);
        let batch: Vec<TrainTriple> = ctx.core_edges[..5]
            .iter()
            .map(|e| TrainTriple { s: e.s, r: e.r, t: e.t, label: 1.0 })
            .collect();
        let a = b.build(&ctx, &batch, 2, g.num_relations);
        let c = b.build(&ctx, &batch, 2, g.num_relations);
        assert_eq!(a.nodes_part, c.nodes_part);
        assert_eq!(a.src, c.src);
        assert_eq!(a.rel, c.rel);
    }

    #[test]
    fn hop_growth_is_monotone() {
        let (g, ctx) = full_ctx();
        let mut b = ComputeGraphBuilder::new(&ctx);
        let batch = [TrainTriple {
            s: ctx.core_edges[0].s,
            r: 0,
            t: ctx.core_edges[0].t,
            label: 1.0,
        }];
        let mut prev = 0;
        for hops in 1..=3 {
            let cg = b.build(&ctx, &batch, hops, g.num_relations);
            assert!(cg.num_nodes() >= prev);
            prev = cg.num_nodes();
        }
    }

    #[test]
    fn avg_closure_size_grows_with_hops() {
        let (_, ctx) = full_ctx();
        let a1 = avg_closure_size(&ctx, 1, 50, 1);
        let a2 = avg_closure_size(&ctx, 2, 50, 1);
        let a3 = avg_closure_size(&ctx, 3, 50, 1);
        assert!(a1 >= 1.0);
        assert!(a2 >= a1 && a3 >= a2, "Figure-2 trend violated: {a1:.1} {a2:.1} {a3:.1}");
    }
}

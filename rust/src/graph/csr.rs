//! CSR (compressed sparse row) adjacency over a triple list.
//!
//! The partitioner's neighborhood expansion and the sampler's
//! compute-graph extraction both need fast "all edges incident to v"
//! queries. We build two CSR indexes over the *same* edge array: one by
//! source (out-edges) and one by target (in-edges). Edge identity is the
//! index into the original triple slice, so callers can map back to
//! relations and to partition membership.

use super::Triple;

/// Immutable CSR index over a fixed edge list.
#[derive(Clone, Debug)]
pub struct Csr {
    num_vertices: usize,
    /// Out index: `out_adj[out_off[v]..out_off[v+1]]` = edge ids with s==v.
    out_off: Vec<u32>,
    out_adj: Vec<u32>,
    /// In index: `in_adj[in_off[v]..in_off[v+1]]` = edge ids with t==v.
    in_off: Vec<u32>,
    in_adj: Vec<u32>,
}

impl Csr {
    /// Build both directions in O(V + E) with counting sort.
    pub fn build(num_vertices: usize, edges: &[Triple]) -> Csr {
        let (out_off, out_adj) = index_by(num_vertices, edges, |e| e.s);
        let (in_off, in_adj) = index_by(num_vertices, edges, |e| e.t);
        Csr { num_vertices, out_off, out_adj, in_off, in_adj }
    }

    pub fn num_vertices(&self) -> usize {
        self.num_vertices
    }

    /// Edge ids whose source is `v`.
    #[inline]
    pub fn out_edges(&self, v: u32) -> &[u32] {
        let v = v as usize;
        &self.out_adj[self.out_off[v] as usize..self.out_off[v + 1] as usize]
    }

    /// Edge ids whose target is `v`.
    #[inline]
    pub fn in_edges(&self, v: u32) -> &[u32] {
        let v = v as usize;
        &self.in_adj[self.in_off[v] as usize..self.in_off[v + 1] as usize]
    }

    #[inline]
    pub fn out_degree(&self, v: u32) -> usize {
        self.out_edges(v).len()
    }

    #[inline]
    pub fn in_degree(&self, v: u32) -> usize {
        self.in_edges(v).len()
    }

    #[inline]
    pub fn degree(&self, v: u32) -> usize {
        self.in_degree(v) + self.out_degree(v)
    }

    /// All edge ids incident to `v` — out-edges first, then in-edges —
    /// without allocating an intermediate `Vec`. The order matches the
    /// `out_edges(v).iter().chain(in_edges(v))` idiom the expansion BFS
    /// and the greedy vertex partitioner both rely on for determinism.
    #[inline]
    pub fn incident(&self, v: u32) -> impl Iterator<Item = u32> + '_ {
        self.out_edges(v).iter().chain(self.in_edges(v)).copied()
    }

    /// Total (in+out) degree of every vertex, read off the offset
    /// arrays. Identical to `KnowledgeGraph::degrees()` over the same
    /// edge list — lets a caller that already built the CSR skip the
    /// extra O(E) counting pass.
    pub fn degrees(&self) -> Vec<u32> {
        (0..self.num_vertices as u32).map(|v| self.degree(v) as u32).collect()
    }
}

fn index_by(num_vertices: usize, edges: &[Triple], vertex: impl Fn(&Triple) -> u32) -> (Vec<u32>, Vec<u32>) {
    let mut counts = vec![0u32; num_vertices + 1];
    for e in edges {
        counts[vertex(e) as usize + 1] += 1;
    }
    for i in 1..counts.len() {
        counts[i] += counts[i - 1];
    }
    let off = counts.clone();
    let mut cursor = counts;
    let mut adj = vec![0u32; edges.len()];
    for (eid, e) in edges.iter().enumerate() {
        let v = vertex(e) as usize;
        adj[cursor[v] as usize] = eid as u32;
        cursor[v] += 1;
    }
    (off, adj)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn edges() -> Vec<Triple> {
        vec![
            Triple::new(0, 0, 1),
            Triple::new(0, 1, 2),
            Triple::new(1, 0, 2),
            Triple::new(2, 0, 0),
            Triple::new(3, 1, 0),
        ]
    }

    #[test]
    fn out_edges_complete_and_correct() {
        let es = edges();
        let csr = Csr::build(4, &es);
        assert_eq!(csr.out_edges(0), &[0, 1]);
        assert_eq!(csr.out_edges(1), &[2]);
        assert_eq!(csr.out_edges(2), &[3]);
        assert_eq!(csr.out_edges(3), &[4]);
        for v in 0..4u32 {
            for &eid in csr.out_edges(v) {
                assert_eq!(es[eid as usize].s, v);
            }
        }
    }

    #[test]
    fn in_edges_complete_and_correct() {
        let es = edges();
        let csr = Csr::build(4, &es);
        let mut in0: Vec<u32> = csr.in_edges(0).to_vec();
        in0.sort();
        assert_eq!(in0, vec![3, 4]);
        assert_eq!(csr.in_degree(2), 2);
        assert_eq!(csr.in_degree(3), 0);
        for v in 0..4u32 {
            for &eid in csr.in_edges(v) {
                assert_eq!(es[eid as usize].t, v);
            }
        }
    }

    #[test]
    fn degrees_sum_to_twice_edges() {
        let es = edges();
        let csr = Csr::build(4, &es);
        let total: usize = (0..4u32).map(|v| csr.degree(v)).sum();
        assert_eq!(total, 2 * es.len());
    }

    #[test]
    fn isolated_vertices_have_empty_slices() {
        let es = vec![Triple::new(0, 0, 1)];
        let csr = Csr::build(5, &es);
        assert!(csr.out_edges(4).is_empty());
        assert!(csr.in_edges(3).is_empty());
    }

    #[test]
    fn empty_graph() {
        let csr = Csr::build(3, &[]);
        assert_eq!(csr.num_vertices(), 3);
        assert!(csr.out_edges(0).is_empty());
    }

    #[test]
    fn incident_matches_chained_slices() {
        let es = edges();
        let csr = Csr::build(4, &es);
        for v in 0..4u32 {
            let want: Vec<u32> =
                csr.out_edges(v).iter().chain(csr.in_edges(v)).copied().collect();
            let got: Vec<u32> = csr.incident(v).collect();
            assert_eq!(got, want);
            assert_eq!(got.len(), csr.degree(v));
        }
    }

    #[test]
    fn degrees_match_per_vertex_degree() {
        let es = edges();
        let csr = Csr::build(4, &es);
        let d = csr.degrees();
        assert_eq!(d.len(), 4);
        for v in 0..4u32 {
            assert_eq!(d[v as usize] as usize, csr.degree(v));
        }
    }
}

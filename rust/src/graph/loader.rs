//! On-disk interchange for datasets: the standard whitespace-separated
//! triple format used by FB15k-237 distributions (`s<TAB>r<TAB>t`, one
//! triple per line, numeric ids here), plus a tiny metadata header file
//! and an optional little-endian f32 feature blob.
//!
//! Layout of a dataset directory:
//! ```text
//! <dir>/meta.json        {"name":..,"entities":N,"relations":R,"feature_dim":F}
//! <dir>/train.tsv        one "s\tr\tt" per line
//! <dir>/valid.tsv
//! <dir>/test.tsv
//! <dir>/features.f32     N*F little-endian f32 (only when F > 0)
//! ```

use super::{KnowledgeGraph, Triple};
use crate::util::json::{self, Json};
use anyhow::{Context, Result};
use std::io::{BufRead, BufWriter, Write};
use std::path::Path;

/// Save a dataset directory (creates it if needed).
pub fn save(g: &KnowledgeGraph, dir: &Path) -> Result<()> {
    std::fs::create_dir_all(dir).with_context(|| format!("creating {dir:?}"))?;
    let meta = Json::obj(vec![
        ("name", Json::Str(g.name.clone())),
        ("entities", Json::Num(g.num_entities as f64)),
        ("relations", Json::Num(g.num_relations as f64)),
        ("feature_dim", Json::Num(g.feature_dim as f64)),
    ]);
    std::fs::write(dir.join("meta.json"), meta.to_string_pretty())?;
    for (name, edges) in [("train", &g.train), ("valid", &g.valid), ("test", &g.test)] {
        write_tsv(&dir.join(format!("{name}.tsv")), edges)?;
    }
    if g.feature_dim > 0 {
        let mut bytes = Vec::with_capacity(g.features.len() * 4);
        for &x in &g.features {
            bytes.extend_from_slice(&x.to_le_bytes());
        }
        std::fs::write(dir.join("features.f32"), bytes)?;
    }
    Ok(())
}

/// Load a dataset directory written by [`save`].
pub fn load(dir: &Path) -> Result<KnowledgeGraph> {
    let meta_text = std::fs::read_to_string(dir.join("meta.json"))
        .with_context(|| format!("reading {dir:?}/meta.json"))?;
    let meta = json::parse(&meta_text)?;
    let name = meta.req_str("name")?.to_string();
    let num_entities = meta.req_usize("entities")?;
    let num_relations = meta.req_usize("relations")?;
    let feature_dim = meta.req_usize("feature_dim")?;

    let train = read_tsv(&dir.join("train.tsv"))?;
    let valid = read_tsv(&dir.join("valid.tsv"))?;
    let test = read_tsv(&dir.join("test.tsv"))?;

    let features = if feature_dim > 0 {
        let bytes = std::fs::read(dir.join("features.f32"))?;
        anyhow::ensure!(
            bytes.len() == num_entities * feature_dim * 4,
            "features.f32 has {} bytes, want {}",
            bytes.len(),
            num_entities * feature_dim * 4
        );
        bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect()
    } else {
        Vec::new()
    };

    let g = KnowledgeGraph {
        name,
        num_entities,
        num_relations,
        train,
        valid,
        test,
        features,
        feature_dim,
    };
    g.check()?;
    Ok(g)
}

fn write_tsv(path: &Path, edges: &[Triple]) -> Result<()> {
    let file = std::fs::File::create(path).with_context(|| format!("creating {path:?}"))?;
    let mut w = BufWriter::new(file);
    for e in edges {
        writeln!(w, "{}\t{}\t{}", e.s, e.r, e.t)?;
    }
    w.flush()?;
    Ok(())
}

fn read_tsv(path: &Path) -> Result<Vec<Triple>> {
    let file = std::fs::File::open(path).with_context(|| format!("opening {path:?}"))?;
    let reader = std::io::BufReader::new(file);
    let mut out = Vec::new();
    for (lineno, line) in reader.lines().enumerate() {
        let line = line?;
        let trimmed = line.trim();
        if trimmed.is_empty() {
            continue;
        }
        let mut parts = trimmed.split_whitespace();
        let parse = |p: Option<&str>| -> Result<u32> {
            p.ok_or_else(|| anyhow::anyhow!("{path:?} line {}: too few fields", lineno + 1))?
                .parse::<u32>()
                .with_context(|| format!("{path:?} line {}: bad id", lineno + 1))
        };
        let s = parse(parts.next())?;
        let r = parse(parts.next())?;
        let t = parse(parts.next())?;
        anyhow::ensure!(
            parts.next().is_none(),
            "{path:?} line {}: too many fields",
            lineno + 1
        );
        out.push(Triple::new(s, r, t));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{DatasetKind, ExperimentConfig};
    use crate::graph::generator;

    fn tmpdir(tag: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir().join(format!("kgscale-loader-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn roundtrip_featureless() {
        let g = generator::generate(&ExperimentConfig::tiny().dataset);
        let dir = tmpdir("plain");
        save(&g, &dir).unwrap();
        let g2 = load(&dir).unwrap();
        assert_eq!(g.train, g2.train);
        assert_eq!(g.valid, g2.valid);
        assert_eq!(g.test, g2.test);
        assert_eq!(g.num_entities, g2.num_entities);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn roundtrip_with_features() {
        let mut cfg = ExperimentConfig::tiny().dataset;
        cfg.kind = DatasetKind::Citation;
        cfg.relations = 1;
        cfg.entities = 400;
        cfg.train_edges = 1500;
        cfg.feature_dim = 6;
        let g = generator::generate(&cfg);
        let dir = tmpdir("feat");
        save(&g, &dir).unwrap();
        let g2 = load(&dir).unwrap();
        assert_eq!(g.features, g2.features);
        assert_eq!(g.feature_dim, 6);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn malformed_lines_are_rejected() {
        let dir = tmpdir("bad");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("meta.json"),
            r#"{"name":"x","entities":3,"relations":1,"feature_dim":0}"#,
        )
        .unwrap();
        std::fs::write(dir.join("train.tsv"), "0\t0\t1\n1 0\n").unwrap();
        std::fs::write(dir.join("valid.tsv"), "").unwrap();
        std::fs::write(dir.join("test.tsv"), "").unwrap();
        assert!(load(&dir).is_err());
        std::fs::write(dir.join("train.tsv"), "0\t0\t9\n").unwrap(); // id out of range
        assert!(load(&dir).is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }
}

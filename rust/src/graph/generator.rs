//! Synthetic dataset generators — the offline stand-ins for FB15k-237 and
//! ogbl-citation2 (see DESIGN.md "Substitutions").
//!
//! Two families:
//!
//! * [`generate_zipf_kg`] — multi-relational KG. Subject and object
//!   entities are drawn from (independently permuted) Zipf distributions,
//!   relations from a Zipf over relation ids. This reproduces the two
//!   properties the paper's partitioning results depend on: heavy-tailed
//!   vertex degrees ("dependencies up to tens of thousands of vertices")
//!   and a skewed relation frequency profile like FB15k-237's.
//! * [`generate_citation`] — single-relation citation graph grown by
//!   preferential attachment (new papers cite earlier papers with
//!   probability ∝ degree+1), with cluster-homophilous wiring and dense
//!   node features from a Gaussian mixture keyed on the cluster, so
//!   features correlate with structure the way Word2Vec title features
//!   correlate with citation communities.
//!
//! Both generators are fully deterministic given the config seed, dedupe
//! edges, guarantee every entity appears in at least one edge, and carve
//! valid/test splits that never overlap train.

use super::{KnowledgeGraph, Triple};
use crate::config::DatasetConfig;
use crate::util::rng::{Rng, Zipf};
use std::collections::HashSet;

/// Generate a dataset according to its config.
pub fn generate(cfg: &DatasetConfig) -> KnowledgeGraph {
    match cfg.kind {
        crate::config::DatasetKind::ZipfKg => generate_zipf_kg(cfg),
        crate::config::DatasetKind::Citation => generate_citation(cfg),
    }
}

/// FB15k-237-style multi-relational KG.
pub fn generate_zipf_kg(cfg: &DatasetConfig) -> KnowledgeGraph {
    let mut rng = Rng::seeded(cfg.seed);
    let n = cfg.entities;
    let total_edges = cfg.train_edges + cfg.valid_edges + cfg.test_edges;
    assert!(total_edges >= n, "need at least one edge per entity (got {total_edges} for {n})");

    // Independent popularity orders for subject and object roles, so the
    // head-heavy and tail-heavy entities differ (as in real KGs).
    let mut subj_order: Vec<u32> = (0..n as u32).collect();
    let mut obj_order: Vec<u32> = (0..n as u32).collect();
    rng.shuffle(&mut subj_order);
    rng.shuffle(&mut obj_order);

    let zipf_e = Zipf::new(n, cfg.zipf_exponent);
    let zipf_r = Zipf::new(cfg.relations, 1.0);

    let mut seen: HashSet<u64> = HashSet::with_capacity(total_edges * 2);
    let mut triples: Vec<Triple> = Vec::with_capacity(total_edges);

    // Guarantee coverage: every entity appears at least once as a subject
    // (so no isolated vertices that cannot be embedded).
    for v in 0..n as u32 {
        let tri = loop {
            let t = obj_order[zipf_e.sample(&mut rng)];
            if t == v {
                continue;
            }
            let r = zipf_r.sample(&mut rng) as u32;
            let tri = Triple::new(v, r, t);
            if seen.insert(tri.key()) {
                break tri;
            }
        };
        triples.push(tri);
    }

    while triples.len() < total_edges {
        let s = subj_order[zipf_e.sample(&mut rng)];
        let t = obj_order[zipf_e.sample(&mut rng)];
        if s == t {
            continue;
        }
        let r = zipf_r.sample(&mut rng) as u32;
        let tri = Triple::new(s, r, t);
        if seen.insert(tri.key()) {
            triples.push(tri);
        }
    }

    split_and_package(cfg, &mut rng, triples, Vec::new(), 0)
}

/// Number of feature clusters for the citation generator's mixture model.
const CITATION_CLUSTERS: usize = 16;
/// Probability a citation stays within the source's cluster.
const HOMOPHILY: f64 = 0.6;
/// Degree cap for the attachment pool: a vertex stops accumulating
/// attachment mass once it has this many pool entries. Uncapped
/// preferential attachment grows super-hubs whose 2-hop ball is the
/// whole graph, which would make every partition expand to the full
/// graph — real citation graphs (and the paper's Table 2, where RF stays
/// well below P on ogbl-citation2) have bounded hub concentration.
const CITATION_DEGREE_CAP: usize = 48;

/// ogbl-citation2-style single-relation graph with features.
pub fn generate_citation(cfg: &DatasetConfig) -> KnowledgeGraph {
    let mut rng = Rng::seeded(cfg.seed);
    let n = cfg.entities;
    let total_edges = cfg.train_edges + cfg.valid_edges + cfg.test_edges;
    assert_eq!(cfg.relations, 1, "citation generator is single-relation");
    assert!(n >= CITATION_CLUSTERS * 2, "citation graph too small");
    assert!(total_edges >= n, "need avg degree >= 1");

    let cluster_of = |v: u32| -> usize { v as usize % CITATION_CLUSTERS };

    // Preferential attachment with homophily. `pool` holds every vertex
    // once per incident edge (+1 smoothing), so uniform pool sampling is
    // degree-proportional; `cluster_pool[c]` is the same restricted to
    // cluster c. Papers cite strictly earlier papers.
    let mut pool: Vec<u32> = Vec::with_capacity(2 * total_edges + n);
    let mut cluster_pool: Vec<Vec<u32>> = vec![Vec::new(); CITATION_CLUSTERS];
    let mut seen: HashSet<u64> = HashSet::with_capacity(total_edges * 2);
    let mut triples: Vec<Triple> = Vec::with_capacity(total_edges);

    let mut pool_count = vec![0u32; n];
    let push = |pool: &mut Vec<u32>, cpool: &mut Vec<Vec<u32>>, pc: &mut [u32], v: u32| {
        if pc[v as usize] as usize >= CITATION_DEGREE_CAP {
            return;
        }
        pc[v as usize] += 1;
        pool.push(v);
        cpool[v as usize % CITATION_CLUSTERS].push(v);
    };
    push(&mut pool, &mut cluster_pool, &mut pool_count, 0);

    // Spread the edge budget across arriving papers: every paper cites at
    // least once; remaining budget is distributed uniformly.
    let extra = total_edges - (n - 1);
    for v in 1..n as u32 {
        let mut cites = 1 + (extra * v as usize / n - extra * (v as usize - 1) / n);
        // Early papers cannot cite more than exist before them.
        cites = cites.min(v as usize);
        let mut attempts = 0;
        let mut placed = 0;
        while placed < cites && attempts < cites * 30 {
            attempts += 1;
            let c = cluster_of(v);
            let use_own = rng.next_f64() < HOMOPHILY && !cluster_pool[c].is_empty();
            // Recency window: papers overwhelmingly cite the recent past
            // (pools are append-ordered, so the window is the tail).
            // This gives the graph the temporal locality real citation
            // graphs have — without it every vertex is within ~3 hops of
            // a hub and neighborhood expansion saturates (RF -> P
            // instead of the paper's sub-P Table 2 trend).
            let window_pick = |rng: &mut Rng, p: &[u32]| -> u32 {
                let w = (p.len() / 32).max(64).min(p.len());
                p[p.len() - 1 - rng.below(w)]
            };
            let t = if use_own {
                window_pick(&mut rng, &cluster_pool[c])
            } else {
                window_pick(&mut rng, &pool)
            };
            if t == v {
                continue;
            }
            let tri = Triple::new(v, 0, t);
            if seen.insert(tri.key()) {
                triples.push(tri);
                push(&mut pool, &mut cluster_pool, &mut pool_count, t);
                placed += 1;
            }
        }
        push(&mut pool, &mut cluster_pool, &mut pool_count, v);
    }

    // Top up to the exact budget with degree-proportional pairs drawn
    // from nearby positions in the (time-ordered) pool, preserving
    // temporal locality.
    let mut stuck = 0;
    while triples.len() < total_edges && stuck < 1_000_000 {
        let i = rng.below(pool.len());
        let w = (pool.len() / 32).max(64);
        let j = (i + 1 + rng.below(w)).min(pool.len() - 1);
        let s = pool[i];
        let t = pool[j];
        if s == t {
            stuck += 1;
            continue;
        }
        let tri = Triple::new(s.max(t), 0, s.min(t)); // later cites earlier
        if seen.insert(tri.key()) {
            triples.push(tri);
            push(&mut pool, &mut cluster_pool, &mut pool_count, s);
            push(&mut pool, &mut cluster_pool, &mut pool_count, t);
            stuck = 0;
        } else {
            stuck += 1;
        }
    }

    // Gaussian-mixture features: cluster mean ± noise.
    let d = cfg.feature_dim;
    let mut features = vec![0f32; n * d];
    if d > 0 {
        let mut means = vec![0f32; CITATION_CLUSTERS * d];
        for m in means.iter_mut() {
            *m = rng.next_gaussian() as f32;
        }
        for v in 0..n {
            let c = cluster_of(v as u32);
            for j in 0..d {
                features[v * d + j] =
                    means[c * d + j] + 0.5 * rng.next_gaussian() as f32;
            }
        }
    }

    split_and_package(cfg, &mut rng, triples, features, d)
}

fn split_and_package(
    cfg: &DatasetConfig,
    rng: &mut Rng,
    mut triples: Vec<Triple>,
    features: Vec<f32>,
    feature_dim: usize,
) -> KnowledgeGraph {
    assert!(
        triples.len() >= cfg.valid_edges + cfg.test_edges + 1,
        "generator produced too few edges ({})",
        triples.len()
    );
    rng.shuffle(&mut triples);
    let test = triples.split_off(triples.len() - cfg.test_edges);
    let valid = triples.split_off(triples.len() - cfg.valid_edges);
    let g = KnowledgeGraph {
        name: cfg.name.clone(),
        num_entities: cfg.entities,
        num_relations: cfg.relations,
        train: triples,
        valid,
        test,
        features,
        feature_dim,
    };
    g.check().expect("generated graph fails self-check");
    g
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{DatasetKind, ExperimentConfig};

    fn zipf_cfg() -> DatasetConfig {
        let mut c = ExperimentConfig::tiny().dataset;
        c.entities = 500;
        c.relations = 10;
        c.train_edges = 4000;
        c.valid_edges = 200;
        c.test_edges = 200;
        c
    }

    fn cite_cfg() -> DatasetConfig {
        DatasetConfig {
            name: "cite-test".into(),
            kind: DatasetKind::Citation,
            entities: 1000,
            relations: 1,
            train_edges: 6000,
            valid_edges: 300,
            test_edges: 300,
            feature_dim: 8,
            zipf_exponent: 1.0,
            seed: 99,
        }
    }

    #[test]
    fn zipf_kg_exact_sizes_and_valid() {
        let g = generate_zipf_kg(&zipf_cfg());
        assert_eq!(g.train.len(), 4000);
        assert_eq!(g.valid.len(), 200);
        assert_eq!(g.test.len(), 200);
        g.check().unwrap();
    }

    #[test]
    fn zipf_kg_deterministic() {
        let a = generate_zipf_kg(&zipf_cfg());
        let b = generate_zipf_kg(&zipf_cfg());
        assert_eq!(a.train, b.train);
        assert_eq!(a.test, b.test);
        let mut c = zipf_cfg();
        c.seed += 1;
        let d = generate_zipf_kg(&c);
        assert_ne!(a.train, d.train);
    }

    #[test]
    fn zipf_kg_no_duplicate_triples_across_splits() {
        let g = generate_zipf_kg(&zipf_cfg());
        let total = g.train.len() + g.valid.len() + g.test.len();
        assert_eq!(g.known_set().len(), total, "duplicate triples");
    }

    #[test]
    fn zipf_kg_degrees_are_skewed() {
        let g = generate_zipf_kg(&zipf_cfg());
        let mut deg = g.degrees();
        deg.sort_unstable_by(|a, b| b.cmp(a));
        // Top-decile should hold a disproportionate share of edges.
        let top: u32 = deg.iter().take(deg.len() / 10).sum();
        let all: u32 = deg.iter().sum();
        assert!(
            top as f64 / all as f64 > 0.3,
            "degree distribution not skewed: top 10% hold {:.2}",
            top as f64 / all as f64
        );
    }

    #[test]
    fn citation_sizes_features_and_dag() {
        let g = generate_citation(&cite_cfg());
        assert_eq!(g.train.len(), 6000);
        assert_eq!(g.feature_dim, 8);
        assert_eq!(g.features.len(), 1000 * 8);
        g.check().unwrap();
        // Citations point backward in time (s > t).
        for e in g.train.iter().chain(&g.valid).chain(&g.test) {
            assert!(e.s > e.t, "citation must point to earlier paper: {e:?}");
        }
    }

    #[test]
    fn citation_deterministic() {
        let a = generate_citation(&cite_cfg());
        let b = generate_citation(&cite_cfg());
        assert_eq!(a.train, b.train);
        assert_eq!(a.features, b.features);
    }

    #[test]
    fn citation_features_are_cluster_homophilous() {
        // Same-cluster vertices should have closer features than
        // different-cluster ones (signal for the GNN).
        let g = generate_citation(&cite_cfg());
        let d = g.feature_dim;
        let dist = |a: u32, b: u32| -> f32 {
            g.feature(a)
                .iter()
                .zip(g.feature(b))
                .map(|(x, y)| (x - y) * (x - y))
                .sum::<f32>()
        };
        // vertices 0 and 16 share cluster (16 clusters, v % 16); 0 and 1 differ.
        let same = dist(0, 16) + dist(1, 17) + dist(2, 18);
        let diff = dist(0, 1) + dist(1, 2) + dist(2, 3);
        assert!(d > 0 && same < diff, "features lack cluster structure: same={same} diff={diff}");
    }

    #[test]
    fn dispatch_matches_kind() {
        let g = generate(&cite_cfg());
        assert_eq!(g.num_relations, 1);
        let g2 = generate(&zipf_cfg());
        assert_eq!(g2.num_relations, 10);
    }
}

//! Graph substrate: knowledge-graph triple storage, CSR adjacency,
//! synthetic dataset generation, and on-disk TSV interchange.
//!
//! A knowledge graph here is a set of triples `(s, r, t)` over `entities`
//! vertices and `relations` relation types, split into train/valid/test
//! edge sets (link-prediction protocol), optionally with dense per-vertex
//! input features (citation-style datasets).

pub mod csr;
pub mod generator;
pub mod loader;

pub use csr::Csr;

/// A single directed labelled edge (s --r--> t).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Triple {
    pub s: u32,
    pub r: u32,
    pub t: u32,
}

impl Triple {
    pub fn new(s: u32, r: u32, t: u32) -> Self {
        Self { s, r, t }
    }

    /// Pack into a u64 key for dedup / filtered-setting membership tests.
    /// Layout: s(24) | r(16) | t(24) — supports up to 16M entities and
    /// 65k relations, asserted in debug builds.
    #[inline]
    pub fn key(&self) -> u64 {
        debug_assert!(self.s < (1 << 24) && self.t < (1 << 24) && self.r < (1 << 16));
        ((self.s as u64) << 40) | ((self.r as u64) << 24) | self.t as u64
    }
}

/// An in-memory knowledge graph with its link-prediction splits.
#[derive(Clone, Debug)]
pub struct KnowledgeGraph {
    pub name: String,
    pub num_entities: usize,
    pub num_relations: usize,
    pub train: Vec<Triple>,
    pub valid: Vec<Triple>,
    pub test: Vec<Triple>,
    /// Row-major [num_entities, feature_dim]; empty when featureless.
    pub features: Vec<f32>,
    pub feature_dim: usize,
}

impl KnowledgeGraph {
    pub fn num_train(&self) -> usize {
        self.train.len()
    }

    /// All triples known to the graph (train ∪ valid ∪ test) as packed
    /// keys — the "filtered setting" membership set of §4.2.
    pub fn known_set(&self) -> std::collections::HashSet<u64> {
        let mut set =
            std::collections::HashSet::with_capacity(self.train.len() + self.valid.len() + self.test.len());
        for tri in self.train.iter().chain(&self.valid).chain(&self.test) {
            set.insert(tri.key());
        }
        set
    }

    /// Degree (in+out over train edges) of every entity.
    pub fn degrees(&self) -> Vec<u32> {
        let mut deg = vec![0u32; self.num_entities];
        for e in &self.train {
            deg[e.s as usize] += 1;
            deg[e.t as usize] += 1;
        }
        deg
    }

    /// Feature row of an entity (empty slice when featureless).
    pub fn feature(&self, v: u32) -> &[f32] {
        if self.feature_dim == 0 {
            return &[];
        }
        let i = v as usize * self.feature_dim;
        &self.features[i..i + self.feature_dim]
    }

    /// Validate internal consistency (entity/relation id ranges, feature
    /// buffer size). Called after generation and after loading from disk.
    pub fn check(&self) -> anyhow::Result<()> {
        for (split, edges) in
            [("train", &self.train), ("valid", &self.valid), ("test", &self.test)]
        {
            for e in edges.iter() {
                if e.s as usize >= self.num_entities || e.t as usize >= self.num_entities {
                    anyhow::bail!("{split}: entity id out of range in {e:?}");
                }
                if e.r as usize >= self.num_relations {
                    anyhow::bail!("{split}: relation id out of range in {e:?}");
                }
            }
        }
        let want = self.num_entities * self.feature_dim;
        if self.features.len() != want {
            anyhow::bail!("feature buffer has {} floats, want {}", self.features.len(), want);
        }
        Ok(())
    }

    /// Table 1-style statistics row.
    pub fn stats(&self) -> DatasetStats {
        DatasetStats {
            name: self.name.clone(),
            entities: self.num_entities,
            relations: self.num_relations,
            features: self.feature_dim,
            train_edges: self.train.len(),
            valid_edges: self.valid.len(),
            test_edges: self.test.len(),
        }
    }
}

/// The columns of the paper's Table 1.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct DatasetStats {
    pub name: String,
    pub entities: usize,
    pub relations: usize,
    pub features: usize,
    pub train_edges: usize,
    pub valid_edges: usize,
    pub test_edges: usize,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_graph() -> KnowledgeGraph {
        KnowledgeGraph {
            name: "t".into(),
            num_entities: 4,
            num_relations: 2,
            train: vec![Triple::new(0, 0, 1), Triple::new(1, 1, 2), Triple::new(2, 0, 3)],
            valid: vec![Triple::new(0, 1, 2)],
            test: vec![Triple::new(3, 0, 0)],
            features: vec![],
            feature_dim: 0,
        }
    }

    #[test]
    fn key_is_injective_on_small_ids() {
        let a = Triple::new(1, 2, 3).key();
        let b = Triple::new(3, 2, 1).key();
        let c = Triple::new(1, 2, 3).key();
        assert_ne!(a, b);
        assert_eq!(a, c);
    }

    #[test]
    fn known_set_covers_all_splits() {
        let g = tiny_graph();
        let set = g.known_set();
        assert_eq!(set.len(), 5);
        assert!(set.contains(&Triple::new(0, 1, 2).key()));
        assert!(set.contains(&Triple::new(3, 0, 0).key()));
        assert!(!set.contains(&Triple::new(0, 0, 2).key()));
    }

    #[test]
    fn degrees_count_both_endpoints() {
        let g = tiny_graph();
        assert_eq!(g.degrees(), vec![1, 2, 2, 1]);
    }

    #[test]
    fn check_catches_out_of_range() {
        let mut g = tiny_graph();
        g.train.push(Triple::new(99, 0, 0));
        assert!(g.check().is_err());
        let mut g2 = tiny_graph();
        g2.train.push(Triple::new(0, 9, 0));
        assert!(g2.check().is_err());
        let mut g3 = tiny_graph();
        g3.feature_dim = 3; // buffer empty -> mismatch
        assert!(g3.check().is_err());
    }

    #[test]
    fn stats_row_matches() {
        let s = tiny_graph().stats();
        assert_eq!(s.entities, 4);
        assert_eq!(s.train_edges, 3);
        assert_eq!(s.valid_edges, 1);
        assert_eq!(s.test_edges, 1);
    }
}

//! Experiment harnesses — one function per paper table/figure (see
//! DESIGN.md's experiment index). Each returns `report::Table`s /
//! `report::Figure`s that the CLI and the `examples/` binaries print and
//! archive under `results/`.
//!
//! The functions take the dataset + artifacts as inputs so the same
//! harness runs at every tier (tiny for CI, the -mini tiers for the
//! recorded EXPERIMENTS.md numbers).

use crate::config::{ExperimentConfig, PartitionConfig, PartitionStrategy};
use crate::eval::{self, FilterIndex};
use crate::graph::{generator, KnowledgeGraph};
use crate::metrics::RunHistory;
use crate::model::Manifest;
use crate::partition::{self, stats as pstats};
use crate::report::{Figure, Table};
use crate::runtime::Runtime;
use crate::sampler::compute_graph::avg_closure_size;
use crate::sampler::PartContext;
use crate::train::Trainer;
use crate::util::stats::humanize_secs;
use anyhow::Result;

/// Table 1: dataset statistics.
pub fn table1(graphs: &[&KnowledgeGraph]) -> Table {
    let mut t = Table::new(
        "Table 1: Dataset statistics",
        &["Dataset", "# Entities", "# Relations", "# Features", "# Train edges", "# Valid edges", "# Test edges"],
    );
    for g in graphs {
        let s = g.stats();
        t.row(vec![
            s.name,
            s.entities.to_string(),
            s.relations.to_string(),
            if s.features == 0 { "-".into() } else { s.features.to_string() },
            s.train_edges.to_string(),
            s.valid_edges.to_string(),
            s.test_edges.to_string(),
        ]);
    }
    t
}

/// Table 2: partition statistics (core/total edges, RF) for a sweep of
/// partition counts under the configured (vertex-cut) strategy + NE.
pub fn table2(
    cfg: &ExperimentConfig,
    graph: &KnowledgeGraph,
    partition_counts: &[usize],
) -> Table {
    let mut t = Table::new(
        "Table 2: Partition statistics (vertex-cut + neighborhood expansion)",
        &["Dataset", "# partitions", "# core edges", "# total edges", "RF"],
    );
    for &p in partition_counts {
        let mut pcfg = cfg.partition.clone();
        pcfg.num_partitions = p;
        let parts = partition::partition_graph(graph, &pcfg, cfg.dataset.seed);
        let s = pstats::compute(&parts, graph.num_entities);
        t.row(vec![
            graph.name.clone(),
            p.to_string(),
            s.core_cell(),
            s.total_cell(),
            format!("{:.2}", s.replication_factor),
        ]);
    }
    t
}

/// Table 2 extended with build diagnostics: per-stage wall-time
/// breakdown (assign / expand / cache-io) and the cache outcome for each
/// partition count, from the production [`partition::build_partitions`]
/// path. Returns the stats alongside the table so callers can log
/// summaries or archive them.
pub fn partition_report(
    cfg: &ExperimentConfig,
    graph: &KnowledgeGraph,
    partition_counts: &[usize],
) -> (Table, Vec<partition::PartitionBuildStats>) {
    let mut t = Table::new(
        "Partition statistics + build breakdown",
        &[
            "Dataset",
            "# partitions",
            "# core edges",
            "# total edges",
            "RF",
            "build (s)",
            "assign (s)",
            "expand (s)",
            "cache-io (s)",
            "cache",
        ],
    );
    let mut all_stats = Vec::new();
    for &p in partition_counts {
        let mut pcfg = cfg.partition.clone();
        pcfg.num_partitions = p;
        let (parts, build) = partition::build_partitions(graph, &pcfg, cfg.dataset.seed);
        let s = pstats::compute(&parts, graph.num_entities);
        t.row(vec![
            graph.name.clone(),
            p.to_string(),
            s.core_cell(),
            s.total_cell(),
            format!("{:.2}", s.replication_factor),
            format!("{:.3}", build.wall_secs),
            format!("{:.3}", build.assign_secs),
            format!("{:.3}", build.expand_secs),
            format!("{:.3}", build.cache_io_secs),
            match (&build.cache_path, build.cache_hit) {
                (None, _) => "off".to_string(),
                (Some(_), true) => "hit".to_string(),
                (Some(_), false) => "miss".to_string(),
            },
        ]);
        all_stats.push(build);
    }
    (t, all_stats)
}

/// One trainer-count run for Table 3: train `epochs`, then evaluate.
pub struct Table3Row {
    pub trainers: usize,
    pub mrr: f64,
    pub hits1: f64,
    pub hits10: f64,
    pub epoch_secs_virtual: f64,
    pub history: RunHistory,
}

/// Run the Table 3 sweep (accuracy parity + scalability).
#[allow(clippy::too_many_arguments)]
pub fn table3_sweep(
    cfg: &ExperimentConfig,
    graph: &KnowledgeGraph,
    runtime: &Runtime,
    manifest: &Manifest,
    trainer_counts: &[usize],
    epochs: usize,
    eval_every: usize,
    eval_triples_cap: usize,
) -> Result<(Table, Vec<Table3Row>)> {
    let filter = FilterIndex::build(graph)?;
    let test: Vec<_> =
        graph.test.iter().take(eval_triples_cap.max(1)).copied().collect();
    // One evaluator for the whole sweep: the padded encode inputs and
    // the rank pool (eval.host_threads) are built once, not per eval.
    let mut evaluator = eval::Evaluator::new(manifest, graph, &cfg.eval)?;
    let mut rows = Vec::new();
    for &p in trainer_counts {
        let mut c = cfg.clone();
        c.train.num_trainers = p;
        let mut trainer = Trainer::new(c, graph, runtime, manifest.clone())?;
        crate::log_info!(
            "table3[{}] P={p}: core edges per worker {:?}",
            cfg.name,
            trainer.worker_core_edges()
        );
        for e in 0..epochs {
            let rec = trainer.train_epoch()?;
            crate::log_info!(
                "table3[{}] P={p} epoch {e}: loss={:.4} virt={} wall={}",
                cfg.name,
                rec.mean_loss,
                humanize_secs(rec.virtual_secs),
                humanize_secs(rec.wall_secs)
            );
            if eval_every > 0 && (e + 1) % eval_every == 0 && e + 1 < epochs {
                let (m, stats) =
                    evaluator.evaluate(runtime, manifest, &trainer.params, &filter, &test)?;
                trainer.record_eval_stats(m.mrr, &stats);
            }
        }
        let (m, stats) =
            evaluator.evaluate(runtime, manifest, &trainer.params, &filter, &test)?;
        trainer.record_eval_stats(m.mrr, &stats);
        rows.push(Table3Row {
            trainers: p,
            mrr: m.mrr,
            hits1: m.hits1,
            hits10: m.hits10,
            epoch_secs_virtual: trainer.history.mean_epoch_virtual_secs(),
            history: trainer.history.clone(),
        });
    }
    let base = rows
        .iter()
        .find(|r| r.trainers == 1)
        .map(|r| r.epoch_secs_virtual)
        .unwrap_or_else(|| rows[0].epoch_secs_virtual);
    let mut t = Table::new(
        &format!("Table 3: RGCN distributed training on {}", graph.name),
        &["#Trainers", "MRR", "Hits@1", "Hits@10", "Ep. time (virtual)", "speedup"],
    );
    for r in &rows {
        t.row(vec![
            r.trainers.to_string(),
            format!("{:.3}", r.mrr),
            format!("{:.3}", r.hits1),
            format!("{:.3}", r.hits10),
            humanize_secs(r.epoch_secs_virtual),
            if r.trainers == 1 {
                "-".into()
            } else {
                format!("{:.2}x", base / r.epoch_secs_virtual)
            },
        ]);
    }
    Ok((t, rows))
}

/// Table 4: fixed number of model updates — fixed batch *count*, so the
/// per-worker batch size shrinks as P grows.
pub fn table4(
    cfg: &ExperimentConfig,
    graph: &KnowledgeGraph,
    runtime: &Runtime,
    manifest: &Manifest,
    trainer_counts: &[usize],
    epochs: usize,
) -> Result<Table> {
    anyhow::ensure!(cfg.train.batch_edges > 0, "table4 needs mini-batch config");
    let base_batch = cfg.train.batch_edges;
    let mut t = Table::new(
        &format!("Table 4: fixed #model updates on {}", graph.name),
        &["#Trainers", "Ep. time (virtual)", "Avg #pos edges per batch", "speedup"],
    );
    let mut base_time = 0.0;
    for &p in trainer_counts {
        let mut c = cfg.clone();
        c.train.num_trainers = p;
        // Same number of updates: batch size scales down with P.
        c.train.batch_edges = (base_batch / p).max(1);
        let mut trainer = Trainer::new(c.clone(), graph, runtime, manifest.clone())?;
        for _ in 0..epochs {
            trainer.train_epoch()?;
        }
        let ep = trainer.history.mean_epoch_virtual_secs();
        if p == trainer_counts[0] {
            base_time = ep * trainer_counts[0] as f64; // normalize to P=1-ish
        }
        t.row(vec![
            p.to_string(),
            humanize_secs(ep),
            c.train.batch_edges.to_string(),
            if base_time > 0.0 { format!("{:.2}x", base_time / (ep * trainer_counts[0] as f64)) } else { "-".into() },
        ]);
        crate::log_info!("table4[{}] P={p}: virt epoch {}", cfg.name, humanize_secs(ep));
    }
    Ok(t)
}

/// Table 5: partitioning-strategy comparison (stats + epoch time) at a
/// fixed partition count.
pub fn table5(
    cfg: &ExperimentConfig,
    graph: &KnowledgeGraph,
    runtime: &Runtime,
    manifest: &Manifest,
    p: usize,
    epochs: usize,
) -> Result<Table> {
    let mut t = Table::new(
        &format!("Table 5: partitioning strategies, {p} partitions, {}", graph.name),
        &["Partitioning", "# core edges", "# total edges", "RF", "Ep. time (virtual)"],
    );
    for (label, strategy) in [
        ("HDRF+NE (KaHIP-sub)", PartitionStrategy::Hdrf),
        ("Greedy-VP+NE (Metis-sub)", PartitionStrategy::MetisLike),
        ("Random+NE", PartitionStrategy::Random),
    ] {
        let mut c = cfg.clone();
        c.partition.strategy = strategy;
        c.train.num_trainers = p;
        let pcfg = PartitionConfig { num_partitions: p, ..c.partition.clone() };
        let parts = partition::partition_graph(graph, &pcfg, cfg.dataset.seed);
        let s = pstats::compute(&parts, graph.num_entities);
        let mut trainer = Trainer::new(c, graph, runtime, manifest.clone())?;
        for _ in 0..epochs {
            trainer.train_epoch()?;
        }
        t.row(vec![
            label.to_string(),
            s.core_cell(),
            s.total_cell(),
            format!("{:.2}", s.replication_factor),
            humanize_secs(trainer.history.mean_epoch_virtual_secs()),
        ]);
        crate::log_info!("table5[{}] {label}: done", cfg.name);
    }
    Ok(t)
}

/// Figure 2: average number of vertices needed to compute one embedding,
/// as a function of hops.
pub fn fig2(cfg: &ExperimentConfig, graph: &KnowledgeGraph, max_hops: usize) -> Figure {
    let mut pcfg = cfg.partition.clone();
    pcfg.num_partitions = 1;
    // hops for partitioning don't matter at P=1; reuse config.
    let parts = partition::partition_graph(graph, &pcfg, cfg.dataset.seed);
    let ctx = PartContext::new(&parts[0]);
    let mut fig = Figure::new(
        "Figure 2: avg vertices per n-hop embedding",
        "hops",
        "avg #vertices",
    );
    let pts: Vec<(f64, f64)> = (1..=max_hops)
        .map(|h| (h as f64, avg_closure_size(&ctx, h, 200, cfg.dataset.seed)))
        .collect();
    fig.add(&graph.name, pts);
    fig
}

/// Figure 6: (a) avg epoch time per trainer count; (b) per-batch
/// component breakdown. Returns (fig_a, table_b) from Table-3 histories.
pub fn fig6(rows: &[Table3Row], dataset: &str) -> (Figure, Table) {
    let mut fig = Figure::new(
        &format!("Figure 6a: avg epoch time, {dataset}"),
        "#trainers",
        "epoch seconds (virtual)",
    );
    fig.add(
        dataset,
        rows.iter().map(|r| (r.trainers as f64, r.epoch_secs_virtual)).collect(),
    );
    let mut t = Table::new(
        &format!("Figure 6b: avg per-batch component time (virtual s), {dataset}"),
        &[
            "#Trainers",
            "getComputeGraph",
            "GNNmodel (fwd+loss+bwd)",
            "sync+step",
            "#batches/epoch",
            "touched rows/step",
            "sync KB/step",
            "prefetch stall (s)",
            "overlap eff",
            "eval wall (s)",
            "rank stall (s)",
            "eval overlap",
        ],
    );
    for r in rows {
        let last = r.history.epochs.last().expect("history nonempty");
        t.row(vec![
            r.trainers.to_string(),
            format!("{:.4}", last.avg_compute_graph),
            format!("{:.4}", last.avg_gnn_model),
            format!("{:.4}", last.avg_sync_step),
            last.num_steps.to_string(),
            // 0 under dense mode, which does not track touched rows.
            format!("{:.0}", last.avg_touched_rows),
            format!("{:.1}", last.avg_sync_bytes / 1024.0),
            // Both 0 on the sequential (host_threads = 0) path.
            format!("{:.4}", last.prefetch_stall_secs),
            format!("{:.2}", last.overlap_efficiency),
            // Eval columns: the periodic eval that followed the final
            // epoch; stall/overlap are 0 with eval.host_threads = 0.
            format!("{:.4}", last.eval_wall_secs),
            format!("{:.4}", last.eval_rank_stall_secs),
            format!("{:.2}", last.eval_overlap_efficiency),
        ]);
    }
    (fig, t)
}

/// Figure 7: convergence — validation MRR vs virtual time for 1 vs P
/// trainers, from Table-3 histories (requires eval_every > 0).
pub fn fig7(rows: &[Table3Row], dataset: &str) -> Figure {
    let mut fig = Figure::new(
        &format!("Figure 7: convergence on {dataset}"),
        "virtual training seconds",
        "validation MRR",
    );
    for r in rows {
        fig.add(
            &format!("{} trainers", r.trainers),
            r.history.eval_points.iter().map(|&(t, _, m)| (t, m)).collect(),
        );
    }
    fig
}

/// Figure 7 companion table: every eval point with its timing breakdown
/// (wall / rank-stall / overlap), so the cost of the periodic evals that
/// produce the convergence curve is visible next to it.
pub fn fig7_table(rows: &[Table3Row], dataset: &str) -> Table {
    let mut t = Table::new(
        &format!("Figure 7 eval points, {dataset}"),
        &["#Trainers", "epoch", "virtual s", "MRR", "eval wall (s)", "rank stall (s)", "overlap"],
    );
    for r in rows {
        for (i, &(tv, epoch, mrr)) in r.history.eval_points.iter().enumerate() {
            // eval_stats parallels eval_points when the run recorded
            // timings; default (zeros) otherwise.
            let s = r.history.eval_stats.get(i).copied().unwrap_or_default();
            t.row(vec![
                r.trainers.to_string(),
                epoch.to_string(),
                format!("{tv:.2}"),
                format!("{mrr:.3}"),
                format!("{:.4}", s.wall_secs),
                format!("{:.4}", s.rank_stall_secs),
                format!("{:.2}", s.overlap_efficiency),
            ]);
        }
    }
    t
}

/// Recovery report: per-epoch fault + checkpoint accounting for a run
/// with the fault layer / periodic checkpointing active. Quiet epochs
/// (no crash, no replay, no straggler inflation, no checkpoint write)
/// are skipped; a totals row closes the table so the overall price of
/// failures is visible at a glance.
pub fn recovery_table(history: &RunHistory, label: &str) -> Table {
    let mut t = Table::new(
        &format!("Recovery report, {label}"),
        &[
            "epoch",
            "crashes",
            "replayed steps",
            "recovery (s)",
            "straggler (s)",
            "ckpt write (s)",
            "virtual (s)",
        ],
    );
    for e in &history.epochs {
        let quiet = e.fault_recoveries == 0
            && e.replayed_steps == 0
            && e.recovery_secs == 0.0
            && e.straggler_secs == 0.0
            && e.checkpoint_write_secs == 0.0;
        if quiet {
            continue;
        }
        t.row(vec![
            e.epoch.to_string(),
            e.fault_recoveries.to_string(),
            e.replayed_steps.to_string(),
            format!("{:.4}", e.recovery_secs),
            format!("{:.4}", e.straggler_secs),
            format!("{:.4}", e.checkpoint_write_secs),
            format!("{:.3}", e.virtual_secs),
        ]);
    }
    t.row(vec![
        "total".to_string(),
        history.total_recoveries().to_string(),
        history.total_replayed_steps().to_string(),
        format!("{:.4}", history.total_recovery_secs()),
        format!("{:.4}", history.epochs.iter().map(|e| e.straggler_secs).sum::<f64>()),
        format!("{:.4}", history.total_checkpoint_write_secs()),
        format!("{:.3}", history.total_virtual_secs()),
    ]);
    t
}

/// Generate the configured dataset (convenience used by CLI + examples).
pub fn dataset(cfg: &ExperimentConfig) -> KnowledgeGraph {
    generator::generate(&cfg.dataset)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExperimentConfig;

    #[test]
    fn table1_rows_match_graphs() {
        let cfg = ExperimentConfig::tiny();
        let g = dataset(&cfg);
        let t = table1(&[&g]);
        assert_eq!(t.rows.len(), 1);
        assert_eq!(t.rows[0][1], g.num_entities.to_string());
    }

    #[test]
    fn table2_has_row_per_partition_count() {
        let cfg = ExperimentConfig::tiny();
        let g = dataset(&cfg);
        let t = table2(&cfg, &g, &[2, 4, 8]);
        assert_eq!(t.rows.len(), 3);
        // RF column increases with partitions
        let rf: Vec<f64> = t.rows.iter().map(|r| r[4].parse().unwrap()).collect();
        assert!(rf[0] <= rf[1] && rf[1] <= rf[2]);
    }

    #[test]
    fn partition_report_matches_table2_stats_and_reports_build() {
        let cfg = ExperimentConfig::tiny();
        let g = dataset(&cfg);
        let (t, stats) = partition_report(&cfg, &g, &[2, 4]);
        let reference = table2(&cfg, &g, &[2, 4]);
        assert_eq!(t.rows.len(), 2);
        assert_eq!(stats.len(), 2);
        for (row, want) in t.rows.iter().zip(&reference.rows) {
            // Shared stat columns agree with the plain Table-2 pipeline.
            assert_eq!(row[..5], want[..5]);
            assert_eq!(row[9], "off", "tiny config has no cache_dir");
        }
        assert!(stats.iter().all(|s| !s.cache_hit && s.cache_path.is_none()));
    }

    #[test]
    fn recovery_table_skips_quiet_epochs_and_totals() {
        use crate::metrics::EpochRecord;
        let mut h = RunHistory::default();
        // Quiet epoch: dropped from the per-epoch rows.
        h.epochs.push(EpochRecord { epoch: 0, virtual_secs: 1.0, ..Default::default() });
        h.epochs.push(EpochRecord {
            epoch: 1,
            virtual_secs: 3.0,
            fault_recoveries: 1,
            replayed_steps: 7,
            recovery_secs: 0.5,
            straggler_secs: 0.25,
            checkpoint_write_secs: 0.125,
            ..Default::default()
        });
        let t = recovery_table(&h, "tiny P=2");
        // One eventful epoch + the totals row.
        assert_eq!(t.rows.len(), 2);
        assert_eq!(t.rows[0][0], "1");
        assert_eq!(t.rows[0][1], "1");
        assert_eq!(t.rows[0][2], "7");
        assert_eq!(t.rows[1][0], "total");
        assert_eq!(t.rows[1][1], "1");
        assert_eq!(t.rows[1][2], "7");
        let md = t.to_markdown();
        assert!(md.contains("crashes"), "markdown header missing: {md}");
        assert!(md.contains("Recovery report"));
    }

    #[test]
    fn fig2_is_monotone() {
        let cfg = ExperimentConfig::tiny();
        let g = dataset(&cfg);
        let f = fig2(&cfg, &g, 3);
        let pts = &f.series[0].points;
        assert_eq!(pts.len(), 3);
        assert!(pts[0].1 <= pts[1].1 && pts[1].1 <= pts[2].1);
    }
}

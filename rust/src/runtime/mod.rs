//! PJRT runtime: load AOT artifacts (HLO text) and execute them.
//!
//! Wraps the `xla` crate (PJRT C API, CPU client). One [`Runtime`] owns
//! the client and an executable cache keyed by artifact file name, so
//! each HLO module is parsed + compiled exactly once per process. The
//! xla wrapper types are not `Send`, so the whole runtime lives on the
//! coordinator thread — the distributed cluster is *simulated* with a
//! virtual clock (see `train::netsim`), which is the documented
//! substitution for the paper's 4-node GPU cluster.

use anyhow::{Context, Result};
use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;

/// Host-side tensor description for building input literals.
pub enum HostTensor<'a> {
    F32(&'a [f32], &'a [i64]),
    I32(&'a [i32], &'a [i64]),
    ScalarI32(i32),
}

/// A compiled entry point.
pub struct Executable {
    pub name: String,
    exe: xla::PjRtLoadedExecutable,
}

impl Executable {
    /// Execute with host inputs; returns the flattened tuple outputs.
    ///
    /// All artifacts are lowered with `return_tuple=True`, so the raw
    /// output is a single tuple literal which we decompose.
    pub fn run(&self, inputs: &[HostTensor<'_>]) -> Result<Vec<xla::Literal>> {
        let literals: Vec<xla::Literal> =
            inputs.iter().map(build_literal).collect::<Result<_>>()?;
        let out = self
            .exe
            .execute::<xla::Literal>(&literals)
            .with_context(|| format!("executing {}", self.name))?;
        let result = out[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetching result of {}", self.name))?;
        Ok(result.to_tuple()?)
    }
}

fn build_literal(t: &HostTensor<'_>) -> Result<xla::Literal> {
    Ok(match t {
        HostTensor::F32(data, dims) => {
            let lit = xla::Literal::vec1(data);
            if dims.len() == 1 {
                debug_assert_eq!(dims[0] as usize, data.len());
                lit
            } else {
                lit.reshape(dims)?
            }
        }
        HostTensor::I32(data, dims) => {
            let lit = xla::Literal::vec1(data);
            if dims.len() == 1 {
                lit
            } else {
                lit.reshape(dims)?
            }
        }
        HostTensor::ScalarI32(v) => xla::Literal::scalar(*v),
    })
}

/// Read a f32 output literal into a Vec.
pub fn literal_to_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    Ok(lit.to_vec::<f32>()?)
}

/// Read a f32 output literal into a caller-owned buffer, reusing its
/// allocation. The hot-path variant of [`literal_to_f32`]: per-batch
/// gradient readback goes through this so `grad_scratch` is allocated
/// once per trainer, not once per batch.
pub fn literal_to_f32_into(lit: &xla::Literal, out: &mut Vec<f32>) -> Result<()> {
    let n = lit.element_count();
    out.clear();
    out.resize(n, 0.0);
    lit.copy_raw_to(out.as_mut_slice())?;
    Ok(())
}

/// Read a scalar f32 output.
pub fn literal_scalar_f32(lit: &xla::Literal) -> Result<f32> {
    Ok(lit.get_first_element::<f32>()?)
}

/// The process-wide PJRT runtime with an executable cache.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    cache: RefCell<HashMap<String, Rc<Executable>>>,
}

impl Runtime {
    /// Create a CPU PJRT client rooted at an artifact directory
    /// (`artifacts/<model_key>/`).
    pub fn new(artifacts_dir: &Path) -> Result<Runtime> {
        anyhow::ensure!(
            artifacts_dir.is_dir(),
            "artifact directory {artifacts_dir:?} does not exist — run `make artifacts`"
        );
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client, dir: artifacts_dir.to_path_buf(), cache: RefCell::new(HashMap::new()) })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an artifact by file name (cached).
    pub fn load(&self, file: &str) -> Result<Rc<Executable>> {
        if let Some(e) = self.cache.borrow().get(file) {
            return Ok(e.clone());
        }
        let path = self.dir.join(file);
        let sw = crate::util::timer::Stopwatch::new();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {file}"))?;
        crate::log_info!("compiled {file} in {:.2}s", sw.elapsed_secs());
        let e = Rc::new(Executable { name: file.to_string(), exe });
        self.cache.borrow_mut().insert(file.to_string(), e.clone());
        Ok(e)
    }

    /// Number of compiled executables currently cached.
    pub fn cached_count(&self) -> usize {
        self.cache.borrow().len()
    }
}

#[cfg(test)]
mod tests {
    // Runtime tests that need real artifacts live in rust/tests/ (they
    // require `make artifacts` to have run). Here: literal glue only.
    use super::*;

    #[test]
    fn f32_literal_roundtrip() {
        let data = [1.0f32, 2.0, 3.0, 4.0];
        let lit = build_literal(&HostTensor::F32(&data, &[4])).unwrap();
        assert_eq!(literal_to_f32(&lit).unwrap(), data.to_vec());
        let lit2 = build_literal(&HostTensor::F32(&data, &[2, 2])).unwrap();
        assert_eq!(lit2.element_count(), 4);
    }

    #[test]
    fn f32_literal_into_reuses_buffer() {
        let data = [5.0f32, 6.0, 7.0];
        let lit = build_literal(&HostTensor::F32(&data, &[3])).unwrap();
        // Pre-sized with stale garbage: must be fully overwritten.
        let mut buf = vec![9.9f32; 8];
        buf.reserve(8);
        let cap = buf.capacity();
        literal_to_f32_into(&lit, &mut buf).unwrap();
        assert_eq!(buf, data.to_vec());
        assert_eq!(buf.capacity(), cap, "readback must not reallocate");
        // Reuse for a second literal.
        let data2 = [1.0f32, 2.0];
        let lit2 = build_literal(&HostTensor::F32(&data2, &[2])).unwrap();
        literal_to_f32_into(&lit2, &mut buf).unwrap();
        assert_eq!(buf, data2.to_vec());
    }

    #[test]
    fn i32_and_scalar_literals() {
        let data = [5i32, -1, 7];
        let lit = build_literal(&HostTensor::I32(&data, &[3])).unwrap();
        assert_eq!(lit.to_vec::<i32>().unwrap(), data.to_vec());
        let s = build_literal(&HostTensor::ScalarI32(42)).unwrap();
        assert_eq!(s.get_first_element::<i32>().unwrap(), 42);
    }
}

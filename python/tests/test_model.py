"""L2 correctness: RGCN+DistMult model — shapes, kernel-path vs ref-path
equivalence, gradients vs finite differences, padding invariance, and the
param-layout contract the Rust side depends on."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

jax.config.update("jax_platform_name", "cpu")
jax.config.update("jax_enable_x64", False)


def tiny_spec(mode="embedding", dropout=0.0):
    return M.ModelSpec(
        name="t", mode=mode, entities=20, relations=3, embed_dim=8,
        num_bases=2, num_layers=2,
        feature_dim=5 if mode == "provided" else 0, dropout=dropout)


def tiny_graph(spec, key, n=12, e=64, b=16):
    """Random padded compute graph with a few masked entries."""
    ks = jax.random.split(key, 8)
    if spec.mode == "embedding":
        node_input = jax.random.randint(ks[0], (n,), 0, spec.entities, jnp.int32)
    else:
        node_input = jax.random.normal(ks[0], (n, spec.feature_dim), jnp.float32)
    src = jax.random.randint(ks[1], (e,), 0, n, jnp.int32)
    dst = jax.random.randint(ks[2], (e,), 0, n, jnp.int32)
    rel = jax.random.randint(ks[3], (e,), 0, spec.msg_relations, jnp.int32)
    edge_mask = (jnp.arange(e) < e - 6).astype(jnp.float32)  # 6 pad edges
    ts = jax.random.randint(ks[4], (b,), 0, n, jnp.int32)
    tr = jax.random.randint(ks[5], (b,), 0, spec.relations, jnp.int32)
    tt = jax.random.randint(ks[6], (b,), 0, n, jnp.int32)
    labels = (jax.random.uniform(ks[7], (b,)) > 0.5).astype(jnp.float32)
    tmask = (jnp.arange(b) < b - 3).astype(jnp.float32)      # 3 pad triples
    return (node_input, src, dst, rel, edge_mask, ts, tr, tt, labels, tmask)


@pytest.mark.parametrize("mode", ["embedding", "provided"])
def test_param_layout_is_contiguous_partition(mode):
    spec = tiny_spec(mode)
    specs = M.param_specs(spec)
    off = 0
    for ps in specs:
        assert ps.offset == off, f"{ps.name} offset {ps.offset} != {off}"
        off += ps.size
    assert off == M.param_count(spec)
    names = [ps.name for ps in specs]
    assert "rel_dec" in names
    if mode == "embedding":
        assert names[0] == "ent_emb"
    else:
        assert names[0] == "proj_w"


@pytest.mark.parametrize("mode", ["embedding", "provided"])
def test_train_step_shapes_and_finiteness(mode):
    spec = tiny_spec(mode)
    key = jax.random.PRNGKey(0)
    flat = M.init_params(spec, key)
    graph = tiny_graph(spec, jax.random.fold_in(key, 1))
    step = M.make_train_step(spec)
    loss, grads = jax.jit(step)(flat, *graph, jnp.int32(7))
    assert loss.shape == ()
    assert grads.shape == flat.shape
    assert np.isfinite(float(loss))
    assert np.all(np.isfinite(np.asarray(grads)))
    assert float(jnp.sum(jnp.abs(grads))) > 0.0


@pytest.mark.parametrize("mode", ["embedding", "provided"])
def test_kernel_path_equals_ref_path(mode):
    spec = tiny_spec(mode)
    key = jax.random.PRNGKey(2)
    flat = M.init_params(spec, key)
    graph = tiny_graph(spec, jax.random.fold_in(key, 3))
    loss_pallas, grads_pallas = M.make_train_step(spec, use_pallas=True)(
        flat, *graph, jnp.int32(0))
    ref_loss = M.reference_loss(spec, flat, *graph)
    ref_grads = jax.grad(
        lambda f: M.reference_loss(spec, f, *graph))(flat)
    np.testing.assert_allclose(loss_pallas, ref_loss, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(grads_pallas, ref_grads, rtol=2e-4, atol=2e-5)


def test_grads_match_finite_differences():
    spec = tiny_spec()
    key = jax.random.PRNGKey(4)
    flat = M.init_params(spec, key)
    graph = tiny_graph(spec, jax.random.fold_in(key, 5), n=8, e=24, b=8)
    loss_fn = lambda f: M.reference_loss(spec, f, *graph)
    g = jax.grad(loss_fn)(flat)
    # Probe a few random coordinates with central differences.
    rng = np.random.default_rng(0)
    idx = rng.choice(flat.shape[0], size=12, replace=False)
    eps = 1e-3
    for i in idx:
        fp = flat.at[i].add(eps)
        fm = flat.at[i].add(-eps)
        fd = (float(loss_fn(fp)) - float(loss_fn(fm))) / (2 * eps)
        assert abs(fd - float(g[i])) < 5e-3 + 0.05 * abs(fd), \
            f"param {i}: fd={fd:.6f} grad={float(g[i]):.6f}"


def test_padding_invariance():
    # Adding masked pad edges/triples must not change loss or grads.
    spec = tiny_spec()
    key = jax.random.PRNGKey(6)
    flat = M.init_params(spec, key)
    (node_input, src, dst, rel, edge_mask,
     ts, tr, tt, labels, tmask) = tiny_graph(spec, jax.random.fold_in(key, 7))
    loss1 = M.reference_loss(spec, flat, node_input, src, dst, rel,
                             edge_mask, ts, tr, tt, labels, tmask)
    # Append pad edges pointing at node 0 and pad triples.
    pad_e = 10
    src2 = jnp.concatenate([src, jnp.zeros(pad_e, jnp.int32)])
    dst2 = jnp.concatenate([dst, jnp.zeros(pad_e, jnp.int32)])
    rel2 = jnp.concatenate([rel, jnp.zeros(pad_e, jnp.int32)])
    em2 = jnp.concatenate([edge_mask, jnp.zeros(pad_e, jnp.float32)])
    pad_b = 5
    ts2 = jnp.concatenate([ts, jnp.zeros(pad_b, jnp.int32)])
    tr2 = jnp.concatenate([tr, jnp.zeros(pad_b, jnp.int32)])
    tt2 = jnp.concatenate([tt, jnp.zeros(pad_b, jnp.int32)])
    lab2 = jnp.concatenate([labels, jnp.ones(pad_b, jnp.float32)])
    tm2 = jnp.concatenate([tmask, jnp.zeros(pad_b, jnp.float32)])
    loss2 = M.reference_loss(spec, flat, node_input, src2, dst2, rel2, em2,
                             ts2, tr2, tt2, lab2, tm2)
    np.testing.assert_allclose(loss1, loss2, rtol=1e-6, atol=1e-6)


def test_loss_sum_decomposes_over_splits():
    # sum-loss over a batch == sum of sum-losses over a 2-way split of the
    # triples (same compute graph) — the property that makes distributed
    # gradient averaging exact.
    spec = tiny_spec()
    key = jax.random.PRNGKey(8)
    flat = M.init_params(spec, key)
    (node_input, src, dst, rel, edge_mask,
     ts, tr, tt, labels, tmask) = tiny_graph(spec, jax.random.fold_in(key, 9))
    full = M.reference_loss(spec, flat, node_input, src, dst, rel, edge_mask,
                            ts, tr, tt, labels, tmask)
    half1 = tmask * (jnp.arange(tmask.shape[0]) % 2 == 0)
    half2 = tmask * (jnp.arange(tmask.shape[0]) % 2 == 1)
    l1 = M.reference_loss(spec, flat, node_input, src, dst, rel, edge_mask,
                          ts, tr, tt, labels, half1)
    l2 = M.reference_loss(spec, flat, node_input, src, dst, rel, edge_mask,
                          ts, tr, tt, labels, half2)
    np.testing.assert_allclose(full, l1 + l2, rtol=1e-5, atol=1e-5)


def test_dropout_is_seeded_and_active():
    spec = tiny_spec(dropout=0.5)
    key = jax.random.PRNGKey(10)
    flat = M.init_params(spec, key)
    graph = tiny_graph(spec, jax.random.fold_in(key, 11))
    step = M.make_train_step(spec)
    l_a, _ = step(flat, *graph, jnp.int32(1))
    l_a2, _ = step(flat, *graph, jnp.int32(1))
    l_b, _ = step(flat, *graph, jnp.int32(2))
    np.testing.assert_allclose(l_a, l_a2)           # same seed -> same loss
    assert abs(float(l_a) - float(l_b)) > 1e-7      # different seed differs


def test_encode_matches_encoder_and_score_ranks():
    spec = tiny_spec()
    key = jax.random.PRNGKey(12)
    flat = M.init_params(spec, key)
    (node_input, src, dst, rel, edge_mask, *_rest) = tiny_graph(
        spec, jax.random.fold_in(key, 13))
    h = M.make_encode(spec)(flat, node_input, src, dst, rel, edge_mask)
    assert h.shape == (node_input.shape[0], spec.embed_dim)
    # score entry: [Q, N] and consistent with pointwise DistMult.
    score = M.make_score(spec)
    params = M.unflatten(spec, flat)
    rel_flat = params["rel_dec"].reshape(-1)
    s_idx = jnp.array([0, 3], jnp.int32)
    r_idx = jnp.array([1, 2], jnp.int32)
    mat = score(h, rel_flat, s_idx, r_idx)
    assert mat.shape == (2, h.shape[0])
    want00 = float(jnp.sum(h[0] * params["rel_dec"][1] * h[0]))
    np.testing.assert_allclose(float(mat[0, 0]), want00, rtol=1e-5)
    want15 = float(jnp.sum(h[3] * params["rel_dec"][2] * h[5]))
    np.testing.assert_allclose(float(mat[1, 5]), want15, rtol=1e-5)


def test_training_reduces_loss():
    # A short plain-SGD loop on a fixed batch must reduce the loss —
    # end-to-end sanity of the model+grads before AOT.
    spec = tiny_spec()
    key = jax.random.PRNGKey(14)
    flat = M.init_params(spec, key)
    graph = tiny_graph(spec, jax.random.fold_in(key, 15))
    step = jax.jit(M.make_train_step(spec))
    tmask_sum = float(jnp.sum(graph[-1]))
    losses = []
    for i in range(30):
        loss, grads = step(flat, *graph, jnp.int32(0))
        losses.append(float(loss) / tmask_sum)
        flat = flat - 0.5 * grads / tmask_sum
    assert losses[-1] < losses[0] * 0.7, f"no learning: {losses[0]:.4f} -> {losses[-1]:.4f}"

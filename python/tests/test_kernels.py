"""L1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes and dtypes; fixed seeds keep runs reproducible.
These are the core correctness signal for the compute hot path — if these
pass, the HLO artifacts embed the same math as ref.py.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (distmult_score, distmult_score_ref,
                             rgcn_basis_message, rgcn_basis_message_ref)

jax.config.update("jax_platform_name", "cpu")


def rand(key, shape, dtype, scale=1.0):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# rgcn_basis_message
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    e_blocks=st.integers(min_value=1, max_value=4),
    d=st.sampled_from([8, 16, 32, 64]),
    nb=st.integers(min_value=1, max_value=4),
    block_e=st.sampled_from([64, 128, 512]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_rgcn_kernel_matches_ref(e_blocks, d, nb, block_e, seed):
    e = e_blocks * block_e
    key = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(key, 3)
    h = rand(k1, (e, d), jnp.float32)
    basis = rand(k2, (nb, d, d), jnp.float32)
    coeff = rand(k3, (e, nb), jnp.float32)
    got = rgcn_basis_message(h, basis, coeff, block_e=block_e)
    want = rgcn_basis_message_ref(h, basis, coeff)
    np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-5)


def test_rgcn_kernel_small_e_single_block():
    # E smaller than the default block must still work (blk = min(blk, E)).
    key = jax.random.PRNGKey(0)
    h = rand(key, (8, 16), jnp.float32)
    basis = rand(key, (2, 16, 16), jnp.float32)
    coeff = rand(key, (8, 2), jnp.float32)
    got = rgcn_basis_message(h, basis, coeff)
    want = rgcn_basis_message_ref(h, basis, coeff)
    np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-5)


def test_rgcn_kernel_bf16_inputs_accumulate_f32():
    key = jax.random.PRNGKey(1)
    h = rand(key, (256, 32), jnp.bfloat16)
    basis = rand(jax.random.fold_in(key, 1), (2, 32, 32), jnp.bfloat16)
    coeff = rand(jax.random.fold_in(key, 2), (256, 2), jnp.bfloat16)
    got = rgcn_basis_message(h, basis, coeff, block_e=128)
    want = rgcn_basis_message_ref(h, basis, coeff)
    assert got.dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=5e-2, atol=5e-2)


def test_rgcn_kernel_rejects_ragged_e():
    key = jax.random.PRNGKey(2)
    h = rand(key, (700, 16), jnp.float32)  # not a multiple of 512
    basis = rand(key, (2, 16, 16), jnp.float32)
    coeff = rand(key, (700, 2), jnp.float32)
    with pytest.raises(AssertionError):
        rgcn_basis_message(h, basis, coeff, block_e=512)


def test_rgcn_kernel_zero_coeff_gives_zero():
    key = jax.random.PRNGKey(3)
    h = rand(key, (128, 16), jnp.float32)
    basis = rand(key, (3, 16, 16), jnp.float32)
    coeff = jnp.zeros((128, 3), jnp.float32)
    got = rgcn_basis_message(h, basis, coeff, block_e=128)
    np.testing.assert_array_equal(np.asarray(got), 0.0)


def test_rgcn_kernel_grad_flows():
    # The kernel must be differentiable (train_step relies on it).
    key = jax.random.PRNGKey(4)
    h = rand(key, (64, 8), jnp.float32)
    basis = rand(jax.random.fold_in(key, 1), (2, 8, 8), jnp.float32)
    coeff = rand(jax.random.fold_in(key, 2), (64, 2), jnp.float32)

    def f(b):
        return jnp.sum(rgcn_basis_message(h, b, coeff, block_e=64) ** 2)

    def f_ref(b):
        return jnp.sum(rgcn_basis_message_ref(h, b, coeff) ** 2)

    g = jax.grad(f)(basis)
    g_ref = jax.grad(f_ref)(basis)
    np.testing.assert_allclose(g, g_ref, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# distmult_score
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    b_blocks=st.integers(min_value=1, max_value=4),
    d=st.sampled_from([4, 16, 32, 75, 128]),
    block_b=st.sampled_from([128, 512, 1024]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_distmult_kernel_matches_ref(b_blocks, d, block_b, seed):
    b = b_blocks * block_b
    key = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(key, 3)
    hs = rand(k1, (b, d), jnp.float32)
    wr = rand(k2, (b, d), jnp.float32)
    ht = rand(k3, (b, d), jnp.float32)
    got = distmult_score(hs, wr, ht, block_b=block_b)
    want = distmult_score_ref(hs, wr, ht)
    np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-5)


def test_distmult_small_batch_single_block():
    key = jax.random.PRNGKey(5)
    hs = rand(key, (7, 12), jnp.float32)
    wr = rand(jax.random.fold_in(key, 1), (7, 12), jnp.float32)
    ht = rand(jax.random.fold_in(key, 2), (7, 12), jnp.float32)
    got = distmult_score(hs, wr, ht)
    want = distmult_score_ref(hs, wr, ht)
    np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-5)


def test_distmult_symmetry():
    # DistMult's diagonal bilinear form is symmetric under s<->t swap —
    # the property the head-corruption evaluator relies on.
    key = jax.random.PRNGKey(6)
    hs = rand(key, (32, 8), jnp.float32)
    wr = rand(jax.random.fold_in(key, 1), (32, 8), jnp.float32)
    ht = rand(jax.random.fold_in(key, 2), (32, 8), jnp.float32)
    np.testing.assert_allclose(distmult_score(hs, wr, ht),
                               distmult_score(ht, wr, hs),
                               rtol=1e-6, atol=1e-6)


def test_distmult_grad_matches_ref():
    key = jax.random.PRNGKey(7)
    hs = rand(key, (16, 8), jnp.float32)
    wr = rand(jax.random.fold_in(key, 1), (16, 8), jnp.float32)
    ht = rand(jax.random.fold_in(key, 2), (16, 8), jnp.float32)
    g = jax.grad(lambda x: jnp.sum(distmult_score(x, wr, ht)))(hs)
    g_ref = jax.grad(lambda x: jnp.sum(distmult_score_ref(x, wr, ht)))(hs)
    np.testing.assert_allclose(g, g_ref, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# rgcn_basis_combine (aggregate-then-transform perf path)
# ---------------------------------------------------------------------------

from compile.kernels import rgcn_basis_combine, rgcn_basis_combine_ref


@settings(max_examples=15, deadline=None)
@given(
    n_blocks=st.integers(min_value=1, max_value=3),
    d=st.sampled_from([8, 32, 64]),
    nb=st.integers(min_value=1, max_value=4),
    block_n=st.sampled_from([64, 512]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_combine_kernel_matches_ref(n_blocks, d, nb, block_n, seed):
    n = n_blocks * block_n
    key = jax.random.PRNGKey(seed)
    agg = rand(key, (nb, n, d), jnp.float32)
    basis = rand(jax.random.fold_in(key, 1), (nb, d, d), jnp.float32)
    got = rgcn_basis_combine(agg, basis, block_n=block_n)
    want = rgcn_basis_combine_ref(agg, basis)
    np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-5)


def test_combine_grad_matches_ref():
    key = jax.random.PRNGKey(8)
    agg = rand(key, (2, 64, 16), jnp.float32)
    basis = rand(jax.random.fold_in(key, 1), (2, 16, 16), jnp.float32)
    g1 = jax.grad(lambda a: jnp.sum(rgcn_basis_combine(a, basis, block_n=64) ** 2))(agg)
    g2 = jax.grad(lambda a: jnp.sum(rgcn_basis_combine_ref(a, basis) ** 2))(agg)
    np.testing.assert_allclose(g1, g2, rtol=1e-4, atol=1e-4)
    b1 = jax.grad(lambda b: jnp.sum(rgcn_basis_combine(agg, b, block_n=64)))(basis)
    b2 = jax.grad(lambda b: jnp.sum(rgcn_basis_combine_ref(agg, b)))(basis)
    np.testing.assert_allclose(b1, b2, rtol=1e-4, atol=1e-4)


def test_fused_equals_unfused_aggregation():
    # The aggregate-then-transform path must be numerically equivalent to
    # the per-edge transform path (linearity of the mean aggregator).
    from compile import model as M
    spec = M.ModelSpec(name="t", mode="embedding", entities=20, relations=3,
                       embed_dim=8, num_bases=2, num_layers=2,
                       feature_dim=0, dropout=0.0)
    key = jax.random.PRNGKey(9)
    flat = M.init_params(spec, key)
    params = M.unflatten(spec, flat)
    n, e = 12, 64
    ks = jax.random.split(key, 4)
    node_input = jax.random.randint(ks[0], (n,), 0, spec.entities, jnp.int32)
    src = jax.random.randint(ks[1], (e,), 0, n, jnp.int32)
    dst = jax.random.randint(ks[2], (e,), 0, n, jnp.int32)
    rel = jax.random.randint(ks[3], (e,), 0, spec.msg_relations, jnp.int32)
    em = (jnp.arange(e) < e - 5).astype(jnp.float32)
    h_fused = M.encoder(spec, params, node_input, src, dst, rel, em, fused=True)
    h_edge = M.encoder(spec, params, node_input, src, dst, rel, em, fused=False)
    np.testing.assert_allclose(h_fused, h_edge, rtol=2e-4, atol=2e-5)

"""L1 Pallas kernel: post-aggregation basis combine — the optimized RGCN
message path (EXPERIMENTS.md §Perf iteration 1).

Because both the basis decomposition and the mean aggregator are linear,
the per-edge transform can be hoisted *after* aggregation:

    agg_b[v] = Σ_{e→v} mask_e · a_{r(e),b} · h[src_e]        (segment sum)
    out[v]   = Σ_b agg_b[v] @ V_b                            (this kernel)

which replaces E-proportional matmul work (E·NB·d² FLOPs in
`rgcn_basis_message`) with N-proportional work (N·NB·d²), an ~E/N ≈ 10x
FLOP cut on our graphs. The coefficient-weighted segment sum stays in XLA
(scatter-add is what the XLA CPU/TPU emitter already does well); this
kernel owns the dense MXU-shaped combine, tiled over N with the basis
stack broadcast to every program — same VMEM strategy as
`rgcn_basis_message`.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_N = 512


def _kernel(agg_ref, basis_ref, out_ref):
    """One [N_BLK, d] tile: out = sum_b agg[b] @ basis[b]."""
    nb = basis_ref.shape[0]
    acc = jnp.zeros(out_ref.shape, jnp.float32)
    for b in range(nb):
        acc = acc + jax.lax.dot_general(
            agg_ref[b], basis_ref[b],
            dimension_numbers=(((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
    out_ref[...] = acc.astype(out_ref.dtype)


def _forward(agg, basis, block_n, interpret):
    nb, n, d = agg.shape
    assert basis.shape == (nb, d, d)
    # Node counts are 64-aligned (plan.rs rounds them up); pick the
    # largest tile <= block_n that divides n so the grid is exact.
    blk = min(block_n, n)
    while n % blk != 0 and blk > 64:
        blk -= 64
    if n % blk != 0:
        blk = n  # degenerate: single tile
    assert n % blk == 0, f"N={n} has no 64-aligned tile <= {block_n}"
    return pl.pallas_call(
        _kernel,
        grid=(n // blk,),
        in_specs=[
            pl.BlockSpec((nb, blk, d), lambda i: (0, i, 0)),
            pl.BlockSpec((nb, d, d), lambda i: (0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((blk, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d), agg.dtype),
        interpret=interpret,
    )(agg, basis)


# VJP: out = Σ_b agg_b @ V_b, cotangent g [N, d]:
#   dagg_b = g @ V_b^T    (the same kernel, transposed basis, broadcast g)
#   dV_b   = agg_b^T @ g
@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def _combine(agg, basis, block_n, interpret):
    return _forward(agg, basis, block_n, interpret)


def _combine_fwd(agg, basis, block_n, interpret):
    return _forward(agg, basis, block_n, interpret), (agg, basis)


def _combine_bwd(block_n, interpret, residuals, g):
    agg, basis = residuals
    nb = basis.shape[0]
    basis_t = jnp.swapaxes(basis, 1, 2)
    # dagg[b] = g @ V_b^T for every b: one matmul per basis (XLA fuses).
    dagg = jnp.einsum("nd,bdj->bnj", g, basis_t,
                      preferred_element_type=jnp.float32).astype(agg.dtype)
    dbasis = jnp.einsum("bni,nj->bij", agg, g,
                        preferred_element_type=jnp.float32).astype(basis.dtype)
    del nb
    return dagg, dbasis


_combine.defvjp(_combine_fwd, _combine_bwd)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def rgcn_basis_combine(agg: jnp.ndarray, basis: jnp.ndarray, *,
                       block_n: int = DEFAULT_BLOCK_N,
                       interpret: bool = True) -> jnp.ndarray:
    """out[v] = Σ_b agg[b, v] @ basis[b]; see module docstring.

    Args:
      agg: [NB, N, d] per-basis aggregated (coefficient-weighted) sums.
      basis: [NB, d, d].

    Returns:
      [N, d]. Differentiable (custom VJP).
    """
    return _combine(agg, basis, block_n, interpret)


def rgcn_basis_combine_ref(agg: jnp.ndarray, basis: jnp.ndarray) -> jnp.ndarray:
    """Pure-jnp oracle."""
    return jnp.einsum("bni,bij->nj", agg, basis,
                      preferred_element_type=jnp.float32).astype(agg.dtype)

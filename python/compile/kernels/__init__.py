"""L1: Pallas kernels for the paper's compute hot spots.

`rgcn_basis.rgcn_basis_message` — the RGCN relation-specific message
transform restructured as basis-count dense matmuls (MXU-shaped);
`distmult.distmult_score` — fused DistMult triple scoring. Both are
checked against the pure-jnp oracles in `ref` by python/tests.
"""

from .distmult import distmult_score
from .ref import distmult_score_ref, rgcn_basis_message_ref
from .rgcn_basis import rgcn_basis_message
from .rgcn_combine import rgcn_basis_combine, rgcn_basis_combine_ref

__all__ = [
    "distmult_score",
    "distmult_score_ref",
    "rgcn_basis_combine",
    "rgcn_basis_combine_ref",
    "rgcn_basis_message",
    "rgcn_basis_message_ref",
]

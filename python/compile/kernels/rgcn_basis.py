"""L1 Pallas kernel: basis-decomposed relational message transform.

The RGCN hot spot is the per-edge relation-specific transform
``msg[e] = W_{r(e)} @ h[src(e)]``. Materializing a [d, d] matrix per edge
is hostile to any matrix unit; the basis decomposition (paper Eq. 2,
``W_r = sum_b a_{rb} V_b``) lets us restructure it as NB *dense* matmuls
over the edge dimension followed by a coefficient-weighted sum:

    msg = sum_b coeff[:, b:b+1] * (h_src @ V_b)          # [E, d]

which is exactly MXU-shaped work (an [E_blk, d] x [d, d] matmul per basis
per tile). This module is the TPU re-think of the paper's P100 kernels —
see DESIGN.md §Hardware-Adaptation.

TPU mapping (estimated in EXPERIMENTS.md §Perf; interpret=True on CPU):
  * grid over E: each program owns an [E_BLK, d] tile of h_src/coeff/out
    resident in VMEM via BlockSpec;
  * the basis stack [NB, d, d] is small (NB*d*d*4 bytes; ≤ 64 KiB for
    d=64, NB=4) and is broadcast to every program (index_map -> block 0);
  * per-tile VMEM = (3*E_BLK*d + NB*d*d + E_BLK*NB) * 4 bytes — E_BLK=512,
    d=64, NB=4 gives ~480 KiB, comfortably under a ~16 MiB VMEM budget,
    leaving room for double buffering;
  * the inner matmul runs on the MXU with f32 accumulation
    (preferred_element_type), so bf16 inputs are safe.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default edge-tile size. Multiple of 8 (f32 sublane) and large enough to
# keep the MXU busy; callers pad E to a multiple of the block.
DEFAULT_BLOCK_E = 512


def _kernel(h_src_ref, basis_ref, coeff_ref, out_ref):
    """One [E_BLK, d] tile: out = sum_b coeff[:, b] * (h_src @ basis[b])."""
    h = h_src_ref[...]                      # [E_BLK, d]
    nb = basis_ref.shape[0]
    acc = jnp.zeros(out_ref.shape, jnp.float32)
    for b in range(nb):                     # NB is small + static: unrolled
        prod = jax.lax.dot_general(
            h, basis_ref[b],
            dimension_numbers=(((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )                                   # [E_BLK, d] on the MXU
        acc = acc + coeff_ref[:, b][:, None].astype(jnp.float32) * prod
    out_ref[...] = acc.astype(out_ref.dtype)


def _forward(h_src, basis, coeff, block_e, interpret):
    """Raw pallas_call wrapper (no AD)."""
    e, d = h_src.shape
    nb = basis.shape[0]
    assert basis.shape == (nb, d, d), f"basis shape {basis.shape}"
    assert coeff.shape == (e, nb), f"coeff shape {coeff.shape}"
    blk = min(block_e, e)
    assert e % blk == 0, f"E={e} must be a multiple of block_e={blk}"
    grid = (e // blk,)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((blk, d), lambda i: (i, 0)),        # h_src tile
            pl.BlockSpec((nb, d, d), lambda i: (0, 0, 0)),   # basis: bcast
            pl.BlockSpec((blk, nb), lambda i: (i, 0)),       # coeff tile
        ],
        out_specs=pl.BlockSpec((blk, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((e, d), h_src.dtype),
        interpret=interpret,
    )(h_src, basis, coeff)


# pallas_call under interpret=True has no reverse-mode rule, so the VJP is
# supplied explicitly. With out = sum_b c_b * (h @ V_b) and cotangent g:
#   dh = sum_b c_b * (g @ V_b^T)      -> the SAME kernel, transposed basis
#   dV_b = h^T @ (c_b * g)            -> NB dense [d,E]x[E,d] matmuls
#   dc[:, b] = sum_j g * (h @ V_b)    -> NB dense matmuls + row reduction
# dh (the big term, [E, d]) reuses the Pallas kernel; the parameter-sized
# terms are left to XLA which fuses them into the surrounding graph.
@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def _message(h_src, basis, coeff, block_e, interpret):
    return _forward(h_src, basis, coeff, block_e, interpret)


def _message_fwd(h_src, basis, coeff, block_e, interpret):
    return _forward(h_src, basis, coeff, block_e, interpret), (h_src, basis, coeff)


def _message_bwd(block_e, interpret, residuals, g):
    h_src, basis, coeff = residuals
    basis_t = jnp.swapaxes(basis, 1, 2)
    dh = _forward(g, basis_t, coeff, block_e, interpret)
    # dV[b] = h^T @ (g * c[:, b, None]); batched over b via einsum.
    dbasis = jnp.einsum("ei,eb,ej->bij", h_src, coeff, g,
                        preferred_element_type=jnp.float32).astype(basis.dtype)
    # dc[e, b] = <g[e], h[e] @ V_b>
    hv = jnp.einsum("ei,bij->ebj", h_src, basis,
                    preferred_element_type=jnp.float32)
    dcoeff = jnp.einsum("ebj,ej->eb", hv, g).astype(coeff.dtype)
    return dh, dbasis, dcoeff


_message.defvjp(_message_fwd, _message_bwd)


@functools.partial(jax.jit, static_argnames=("block_e", "interpret"))
def rgcn_basis_message(h_src: jnp.ndarray, basis: jnp.ndarray,
                       coeff: jnp.ndarray, *, block_e: int = DEFAULT_BLOCK_E,
                       interpret: bool = True) -> jnp.ndarray:
    """Per-edge basis-decomposed messages; see module docstring.

    Args:
      h_src: [E, d] source hidden states (E must divide by block_e, or be
        smaller than one block).
      basis: [NB, d, d] basis matrices.
      coeff: [E, NB] per-edge coefficients.
      block_e: edge-tile size.
      interpret: lower via the Pallas interpreter (required for CPU PJRT —
        real TPU lowering emits Mosaic custom-calls the CPU cannot run).

    Returns:
      [E, d] messages, dtype of h_src. Differentiable (custom VJP).
    """
    return _message(h_src, basis, coeff, block_e, interpret)


def vmem_bytes(block_e: int, d: int, nb: int, dtype_bytes: int = 4) -> int:
    """Estimated per-program VMEM residency — used by the §Perf report."""
    return dtype_bytes * (2 * block_e * d        # h_src tile + out tile
                          + nb * d * d           # basis stack
                          + block_e * nb         # coeff tile
                          + block_e * d)         # f32 accumulator

"""Pure-jnp correctness oracles for the Pallas kernels.

Every kernel in this package has a reference implementation here written
with nothing but ``jax.numpy`` primitives. ``python/tests/test_kernels.py``
sweeps shapes/dtypes (hypothesis) asserting kernel == ref; the L2 model
can also be built against the refs (``use_pallas=False``) so model-level
tests isolate kernel bugs from model bugs.
"""

import jax.numpy as jnp


def rgcn_basis_message_ref(h_src: jnp.ndarray, basis: jnp.ndarray,
                           coeff: jnp.ndarray) -> jnp.ndarray:
    """Per-edge basis-decomposed relational transform (paper Eq. 1-2).

    msg[e] = sum_b coeff[e, b] * (h_src[e] @ basis[b])

    Args:
      h_src: [E, d]  gathered source hidden states.
      basis: [NB, d, d]  shared basis matrices V_b.
      coeff: [E, NB]  per-edge relation coefficients a_{r(e), b}.

    Returns:
      [E, d] messages.
    """
    return jnp.einsum(
        "ei,bij,eb->ej", h_src, basis, coeff,
        preferred_element_type=jnp.float32,
    ).astype(h_src.dtype)


def distmult_score_ref(hs: jnp.ndarray, wr: jnp.ndarray,
                       ht: jnp.ndarray) -> jnp.ndarray:
    """DistMult triple score (paper Eq. 4): score[i] = <hs[i], wr[i], ht[i]>.

    Args:
      hs, wr, ht: [B, d] head embedding, relation diagonal, tail embedding.

    Returns:
      [B] scores.
    """
    return jnp.sum(hs * wr * ht, axis=-1)

"""L1 Pallas kernel: DistMult triple scoring (paper Eq. 4).

score[i] = <hs[i], wr[i], ht[i]> — a fused elementwise-product row
reduction. One VPU-shaped pass per [B_BLK, d] tile: the three operand
tiles stream through VMEM once and reduce to a [B_BLK] lane, so the
kernel is purely bandwidth-bound (arithmetic intensity 1 FLOP/byte).

On TPU the natural layout is d on the lane dimension (d ≤ 128 for every
config in this repo, so a row is a single vreg row); interpret=True is
used for CPU execution as everywhere else.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_B = 1024


def _kernel(hs_ref, wr_ref, ht_ref, out_ref):
    prod = (hs_ref[...].astype(jnp.float32)
            * wr_ref[...].astype(jnp.float32)
            * ht_ref[...].astype(jnp.float32))
    out_ref[...] = jnp.sum(prod, axis=-1).astype(out_ref.dtype)


def _forward(hs, wr, ht, block_b, interpret):
    b, d = hs.shape
    assert wr.shape == (b, d) and ht.shape == (b, d)
    blk = min(block_b, b)
    assert b % blk == 0, f"B={b} must be a multiple of block_b={blk}"
    return pl.pallas_call(
        _kernel,
        grid=(b // blk,),
        in_specs=[
            pl.BlockSpec((blk, d), lambda i: (i, 0)),
            pl.BlockSpec((blk, d), lambda i: (i, 0)),
            pl.BlockSpec((blk, d), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((blk,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((b,), jnp.float32),
        interpret=interpret,
    )(hs, wr, ht)


# Explicit VJP (interpret-mode pallas_call has no reverse-mode rule):
# score = sum(hs*wr*ht); d hs = g[:,None]*wr*ht etc. — pure VPU work that
# XLA fuses, so the backward needs no kernel of its own.
@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def _score(hs, wr, ht, block_b, interpret):
    return _forward(hs, wr, ht, block_b, interpret)


def _score_fwd(hs, wr, ht, block_b, interpret):
    return _forward(hs, wr, ht, block_b, interpret), (hs, wr, ht)


def _score_bwd(block_b, interpret, residuals, g):
    hs, wr, ht = residuals
    gb = g[:, None].astype(jnp.float32)
    dhs = (gb * wr * ht).astype(hs.dtype)
    dwr = (gb * hs * ht).astype(wr.dtype)
    dht = (gb * hs * wr).astype(ht.dtype)
    return dhs, dwr, dht


_score.defvjp(_score_fwd, _score_bwd)


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def distmult_score(hs: jnp.ndarray, wr: jnp.ndarray, ht: jnp.ndarray, *,
                   block_b: int = DEFAULT_BLOCK_B,
                   interpret: bool = True) -> jnp.ndarray:
    """Batched DistMult scores.

    Args:
      hs, wr, ht: [B, d] head embeddings, relation diagonals (gathered per
        triple), tail embeddings. B must divide by block_b or fit one block.

    Returns:
      [B] scores in f32. Differentiable (custom VJP).
    """
    return _score(hs, wr, ht, block_b, interpret)

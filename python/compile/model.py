"""L2: the paper's model as a JAX computation — RGCN encoder (basis
decomposition, Eq. 1-2) + DistMult decoder (Eq. 4) + binary cross-entropy
over sampled negatives (Eq. 3), with ``jax.grad`` providing the backward
pass. Calls the L1 Pallas kernels for the two hot spots.

Everything here is *build-time only*: ``aot.py`` lowers the entry points
built by :func:`make_train_step`, :func:`make_encode` and
:func:`make_score` to HLO text once; the Rust coordinator executes those
artifacts and never imports Python.

Parameter handling: all parameters live in one flat f32 vector whose
layout (:func:`param_specs`) is exported in the artifact manifest. The
Rust side owns the vector (init, Adam step, AllReduce); entry points take
it as their first input and gradients come back in the same layout, so
L3 never needs to understand model structure.

Shape/padding contract with L3 (see rust/src/model):
  * nodes, edges, and triples are padded to the entry's static sizes;
  * pad edges have ``edge_mask == 0`` and point at node 0;
  * pad triples have ``triple_mask == 0`` and index node 0;
  * ``train_step`` returns the *sum* of per-triple losses and the
    gradients of that sum — the trainer divides by the global triple
    count after AllReduce, which makes distributed gradients exactly
    equal to single-worker full-batch gradients (§2.2's mathematical
    equivalence).
"""

import dataclasses
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from .kernels import (distmult_score, distmult_score_ref,
                      rgcn_basis_combine, rgcn_basis_combine_ref,
                      rgcn_basis_message, rgcn_basis_message_ref)

# Aggregate-then-transform (EXPERIMENTS.md §Perf iteration 1): hoist the
# basis matmuls after the (linear) mean aggregation, cutting the message
# transform from E·NB·d² to N·NB·d² FLOPs. Both paths are kept — tests
# assert they agree — and AOT lowers the fused one.
FUSED_AGGREGATION = True


@dataclasses.dataclass(frozen=True)
class ModelSpec:
    """Static model hyperparameters (mirrors rust config::ModelConfig)."""
    name: str
    mode: str                 # "embedding" | "provided"
    entities: int             # total entities N_total (embedding table rows)
    relations: int            # base relation count R (decoder rows)
    embed_dim: int            # d
    num_bases: int            # NB
    num_layers: int           # L (= partition hops)
    feature_dim: int          # F (provided mode only)
    dropout: float

    @property
    def msg_relations(self) -> int:
        """Relations seen by message passing: forward + inverse."""
        return 2 * self.relations

    @staticmethod
    def from_dict(d: dict) -> "ModelSpec":
        return ModelSpec(
            name=d["name"], mode=d["mode"], entities=int(d["entities"]),
            relations=int(d["relations"]), embed_dim=int(d["embed_dim"]),
            num_bases=int(d["num_bases"]), num_layers=int(d["num_layers"]),
            feature_dim=int(d.get("feature_dim", 0)),
            dropout=float(d.get("dropout", 0.0)),
        )


# --------------------------------------------------------------------------
# Parameter layout
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ParamSpec:
    name: str
    shape: Tuple[int, ...]
    offset: int
    init: str        # "xavier_uniform" | "zeros"
    fan_in: int
    fan_out: int

    @property
    def size(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n


def param_specs(spec: ModelSpec) -> List[ParamSpec]:
    """The flat-vector layout. Order is the contract with Rust: never
    reorder without bumping the manifest version."""
    out: List[ParamSpec] = []
    off = 0

    def add(name, shape, init="xavier_uniform", fan=None):
        nonlocal off
        fan_in, fan_out = fan if fan else (
            shape[-2] if len(shape) >= 2 else shape[-1], shape[-1])
        ps = ParamSpec(name, tuple(shape), off, init, fan_in, fan_out)
        out.append(ps)
        off += ps.size

    d = spec.embed_dim
    if spec.mode == "embedding":
        add("ent_emb", (spec.entities, d), fan=(d, d))
    else:
        add("proj_w", (spec.feature_dim, d))
        add("proj_b", (d,), init="zeros")
    for layer in range(spec.num_layers):
        add(f"basis_{layer}", (spec.num_bases, d, d), fan=(d, d))
        add(f"coeff_{layer}", (spec.msg_relations, spec.num_bases),
            fan=(spec.num_bases, spec.num_bases))
        add(f"self_w_{layer}", (d, d))
        add(f"bias_{layer}", (d,), init="zeros")
    add("rel_dec", (spec.relations, d), fan=(d, d))
    return out


def param_count(spec: ModelSpec) -> int:
    specs = param_specs(spec)
    return specs[-1].offset + specs[-1].size


def unflatten(spec: ModelSpec, flat: jnp.ndarray) -> Dict[str, jnp.ndarray]:
    """Slice the flat vector into named parameter arrays (differentiable)."""
    params = {}
    for ps in param_specs(spec):
        params[ps.name] = jax.lax.dynamic_slice_in_dim(
            flat, ps.offset, ps.size).reshape(ps.shape)
    return params


def init_params(spec: ModelSpec, key: jax.Array) -> jnp.ndarray:
    """Python-side initializer — used by tests; Rust re-implements this
    from the manifest (same distribution family, its own RNG)."""
    chunks = []
    for ps in param_specs(spec):
        key, sub = jax.random.split(key)
        if ps.init == "zeros":
            chunks.append(jnp.zeros(ps.size, jnp.float32))
        else:
            limit = (6.0 / (ps.fan_in + ps.fan_out)) ** 0.5
            chunks.append(jax.random.uniform(
                sub, (ps.size,), jnp.float32, -limit, limit))
    return jnp.concatenate(chunks)


# --------------------------------------------------------------------------
# Encoder / decoder
# --------------------------------------------------------------------------

def _segment_sum(data: jnp.ndarray, segment_ids: jnp.ndarray,
                 num_segments: int) -> jnp.ndarray:
    return jax.ops.segment_sum(data, segment_ids, num_segments=num_segments)


def encoder(spec: ModelSpec, params: Dict[str, jnp.ndarray],
            node_input: jnp.ndarray, src: jnp.ndarray, dst: jnp.ndarray,
            rel: jnp.ndarray, edge_mask: jnp.ndarray, *,
            dropout_key=None, use_pallas: bool = True,
            fused: bool = FUSED_AGGREGATION) -> jnp.ndarray:
    """L-layer RGCN over a (padded) compute graph.

    node_input: [N] int32 global entity ids (embedding mode) or
                [N, F] f32 features (provided mode).
    src/dst/rel: [E] int32 message edges in cg-local ids; rel already
                 includes the +R inverse offset.
    edge_mask:   [E] f32, 0.0 for padding.

    Returns [N, d] final hidden states.
    """
    msg_fn = rgcn_basis_message if use_pallas else rgcn_basis_message_ref
    if spec.mode == "embedding":
        h = params["ent_emb"][node_input]                     # [N, d]
    else:
        h = node_input @ params["proj_w"] + params["proj_b"]  # [N, d]
    n = h.shape[0]

    # Mean aggregation: 1/|N(v)| with padding excluded (paper Eq. 1, Agg
    # = MEAN). deg counts real in-messages per node.
    deg = _segment_sum(edge_mask, dst, n)                     # [N]
    inv_deg = jnp.where(deg > 0, 1.0 / jnp.maximum(deg, 1.0), 0.0)[:, None]

    combine_fn = rgcn_basis_combine if use_pallas else rgcn_basis_combine_ref

    for layer in range(spec.num_layers):
        basis = params[f"basis_{layer}"]                      # [NB, d, d]
        coeff_tab = params[f"coeff_{layer}"]                  # [2R, NB]
        h_src = h[src]                                        # [E, d]
        coeff = coeff_tab[rel]                                # [E, NB]
        if fused:
            # Aggregate-then-transform: weighted per-basis segment sums
            # (E·NB·d mults, XLA scatter-add) then one N-proportional
            # basis combine on the matrix unit.
            weighted = (h_src[:, None, :]
                        * (coeff * edge_mask[:, None])[:, :, None])  # [E, NB, d]
            agg_b = _segment_sum(weighted, dst, n)            # [N, NB, d]
            agg_b = jnp.swapaxes(agg_b, 0, 1)                 # [NB, N, d]
            agg = combine_fn(agg_b, basis) * inv_deg          # [N, d]
        else:
            msg = msg_fn(h_src, basis, coeff)                 # [E, d]
            msg = msg * edge_mask[:, None]
            agg = _segment_sum(msg, dst, n) * inv_deg         # [N, d]
        h_new = agg + h @ params[f"self_w_{layer}"] + params[f"bias_{layer}"]
        if layer + 1 < spec.num_layers:
            h_new = jax.nn.relu(h_new)
        if dropout_key is not None and spec.dropout > 0.0:
            keep = 1.0 - spec.dropout
            mask = jax.random.bernoulli(
                jax.random.fold_in(dropout_key, layer), keep, h_new.shape)
            h_new = jnp.where(mask, h_new / keep, 0.0)
        h = h_new
    return h


def decoder(spec: ModelSpec, params: Dict[str, jnp.ndarray], h: jnp.ndarray,
            ts: jnp.ndarray, tr: jnp.ndarray, tt: jnp.ndarray, *,
            use_pallas: bool = True) -> jnp.ndarray:
    """DistMult logits for a batch of (padded) triples."""
    score_fn = distmult_score if use_pallas else distmult_score_ref
    hs = h[ts]                                # [B, d]
    wr = params["rel_dec"][tr]                # [B, d]
    ht = h[tt]                                # [B, d]
    return score_fn(hs, wr, ht)               # [B]


def bce_loss_sum(logits: jnp.ndarray, labels: jnp.ndarray,
                 mask: jnp.ndarray) -> jnp.ndarray:
    """Numerically-stable summed binary cross-entropy (Eq. 3 numerator)."""
    per = jnp.maximum(logits, 0.0) - logits * labels + jnp.log1p(
        jnp.exp(-jnp.abs(logits)))
    return jnp.sum(per * mask)


# --------------------------------------------------------------------------
# AOT entry points
# --------------------------------------------------------------------------

def make_train_step(spec: ModelSpec, *, use_pallas: bool = True):
    """Build train_step(flat_params, node_input, src, dst, rel, edge_mask,
    ts, tr, tt, labels, tmask, seed) -> (sum_loss, grads_flat)."""

    def loss_fn(flat, node_input, src, dst, rel, edge_mask,
                ts, tr, tt, labels, tmask, seed):
        params = unflatten(spec, flat)
        dropout_key = (jax.random.PRNGKey(seed)
                       if spec.dropout > 0.0 else None)
        h = encoder(spec, params, node_input, src, dst, rel, edge_mask,
                    dropout_key=dropout_key, use_pallas=use_pallas)
        logits = decoder(spec, params, h, ts, tr, tt, use_pallas=use_pallas)
        return bce_loss_sum(logits, labels, tmask)

    def train_step(flat, node_input, src, dst, rel, edge_mask,
                   ts, tr, tt, labels, tmask, seed):
        loss, grads = jax.value_and_grad(loss_fn)(
            flat, node_input, src, dst, rel, edge_mask,
            ts, tr, tt, labels, tmask, seed)
        return loss, grads

    return train_step


def make_encode(spec: ModelSpec, *, use_pallas: bool = True):
    """Build encode(flat_params, node_input, src, dst, rel, edge_mask)
    -> h [N, d]; dropout disabled (inference)."""

    def encode(flat, node_input, src, dst, rel, edge_mask):
        params = unflatten(spec, flat)
        return encoder(spec, params, node_input, src, dst, rel, edge_mask,
                       dropout_key=None, use_pallas=use_pallas)

    return encode


def make_score(spec: ModelSpec, *, use_pallas: bool = True):
    """Build score(h, rel_dec_flat, s_idx, r_idx) -> [Q, N] ranking scores.

    scores[q, c] = <h[s_idx[q]] * rel[r_idx[q]], h[c]> — DistMult against
    every candidate entity at once; used by the filtered-MRR evaluator for
    both tail corruption (pass heads as s_idx) and head corruption (pass
    tails — DistMult's bilinear-diagonal form is symmetric in s/t roles).
    """
    del use_pallas  # the all-candidates form is a plain matmul

    def score(h, rel_dec_flat, s_idx, r_idx):
        rel = rel_dec_flat.reshape(spec.relations, spec.embed_dim)
        q = h[s_idx] * rel[r_idx]             # [Q, d]
        return q @ h.T                        # [Q, N]

    return score


# --------------------------------------------------------------------------
# Reference full-model forward (oracle for python tests)
# --------------------------------------------------------------------------

def reference_loss(spec: ModelSpec, flat, node_input, src, dst, rel,
                   edge_mask, ts, tr, tt, labels, tmask) -> jnp.ndarray:
    """Same computation as train_step's loss with the pure-jnp kernels and
    no dropout — the model-level oracle."""
    params = unflatten(spec, flat)
    h = encoder(spec, params, node_input, src, dst, rel, edge_mask,
                dropout_key=None, use_pallas=False, fused=False)
    logits = decoder(spec, params, h, ts, tr, tt, use_pallas=False)
    return bce_loss_sum(logits, labels, tmask)

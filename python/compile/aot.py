"""AOT compiler: lower the L2 entry points to HLO *text* artifacts.

Usage:
    python -m compile.aot --plan <plan.json> --out <artifacts/dir>

The plan file is produced by ``kgscale plan`` (Rust), which partitions the
dataset and measures the exact padded sizes every trainer configuration
needs (compute-graph node/edge/triple maxima rounded up to kernel block
multiples). This file only lowers what the plan asks for and writes:

    <out>/train_step_n{N}_e{E}_b{B}.hlo.txt     (one per bucket)
    <out>/encode_n{N}_e{E}.hlo.txt
    <out>/score_q{Q}_n{N}.hlo.txt
    <out>/manifest.json                          (shapes + param layout)

Interchange is HLO text, not serialized HloModuleProto: jax >= 0.5 emits
protos with 64-bit instruction ids that the xla crate's xla_extension
0.5.1 rejects; the text parser reassigns ids (see /opt/xla-example).

Skips lowering when the artifact already exists and is newer than this
package's sources, so ``make artifacts`` is an incremental no-op.
"""

import argparse
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .model import ModelSpec, make_encode, make_score, make_train_step
from .model import param_count, param_specs

# Kernel block sizes (keep in sync with kernels/*.py defaults): padded E
# must be a multiple of EDGE_BLOCK, padded B of TRIPLE_BLOCK (or smaller
# than one block).
EDGE_BLOCK = 512
TRIPLE_BLOCK = 1024


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def node_input_sds(spec: ModelSpec, n: int):
    if spec.mode == "embedding":
        return _sds((n,), jnp.int32)
    return _sds((n, spec.feature_dim), jnp.float32)


def lower_train_step(spec: ModelSpec, n: int, e: int, b: int) -> str:
    fn = make_train_step(spec)
    p = param_count(spec)
    lowered = jax.jit(fn, keep_unused=True).lower(
        _sds((p,), jnp.float32),              # flat params
        node_input_sds(spec, n),              # node ids / features
        _sds((e,), jnp.int32),                # src
        _sds((e,), jnp.int32),                # dst
        _sds((e,), jnp.int32),                # rel (with inverse offset)
        _sds((e,), jnp.float32),              # edge_mask
        _sds((b,), jnp.int32),                # ts
        _sds((b,), jnp.int32),                # tr
        _sds((b,), jnp.int32),                # tt
        _sds((b,), jnp.float32),              # labels
        _sds((b,), jnp.float32),              # triple mask
        _sds((), jnp.int32),                  # dropout seed
    )
    return to_hlo_text(lowered)


def lower_encode(spec: ModelSpec, n: int, e: int) -> str:
    fn = make_encode(spec)
    p = param_count(spec)
    lowered = jax.jit(fn, keep_unused=True).lower(
        _sds((p,), jnp.float32),
        node_input_sds(spec, n),
        _sds((e,), jnp.int32),
        _sds((e,), jnp.int32),
        _sds((e,), jnp.int32),
        _sds((e,), jnp.float32),
    )
    return to_hlo_text(lowered)


def lower_score(spec: ModelSpec, q: int, n: int) -> str:
    fn = make_score(spec)
    lowered = jax.jit(fn, keep_unused=True).lower(
        _sds((n, spec.embed_dim), jnp.float32),
        _sds((spec.relations * spec.embed_dim,), jnp.float32),
        _sds((q,), jnp.int32),
        _sds((q,), jnp.int32),
    )
    return to_hlo_text(lowered)


def check_bucket(n: int, e: int, b: int) -> None:
    assert e % EDGE_BLOCK == 0 or e < EDGE_BLOCK, \
        f"edges {e} not a multiple of {EDGE_BLOCK}"
    assert b % TRIPLE_BLOCK == 0 or b < TRIPLE_BLOCK, \
        f"triples {b} not a multiple of {TRIPLE_BLOCK}"
    assert n > 0 and e > 0 and b > 0


def sources_mtime() -> float:
    pkg = os.path.dirname(os.path.abspath(__file__))
    newest = 0.0
    for root, _, files in os.walk(pkg):
        for f in files:
            if f.endswith(".py"):
                newest = max(newest, os.path.getmtime(os.path.join(root, f)))
    return newest


def emit(path: str, produce, stale_after: float, force: bool) -> bool:
    """Write `produce()` to path unless it is already fresh."""
    if (not force and os.path.exists(path)
            and os.path.getmtime(path) >= stale_after):
        print(f"  fresh    {os.path.basename(path)}")
        return False
    text = produce()
    with open(path, "w") as f:
        f.write(text)
    print(f"  lowered  {os.path.basename(path)} ({len(text) / 1e6:.2f} MB)")
    return True


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--plan", required=True, help="plan JSON from `kgscale plan`")
    ap.add_argument("--out", required=True, help="artifact output directory")
    ap.add_argument("--force", action="store_true", help="re-lower everything")
    args = ap.parse_args()

    with open(args.plan) as f:
        plan = json.load(f)
    spec = ModelSpec.from_dict(plan)
    os.makedirs(args.out, exist_ok=True)
    stale_after = max(sources_mtime(), os.path.getmtime(args.plan))

    entries = []
    print(f"[aot] {spec.name}: mode={spec.mode} d={spec.embed_dim} "
          f"NB={spec.num_bases} L={spec.num_layers} "
          f"params={param_count(spec)}")

    for n, e, b in plan["train_buckets"]:
        check_bucket(n, e, b)
        fname = f"train_step_n{n}_e{e}_b{b}.hlo.txt"
        emit(os.path.join(args.out, fname),
             lambda n=n, e=e, b=b: lower_train_step(spec, n, e, b),
             stale_after, args.force)
        entries.append({"kind": "train_step", "file": fname,
                        "nodes": n, "edges": e, "triples": b})

    enc_n, enc_e = plan["encode"]
    check_bucket(enc_n, enc_e, 1)
    fname = f"encode_n{enc_n}_e{enc_e}.hlo.txt"
    emit(os.path.join(args.out, fname),
         lambda: lower_encode(spec, enc_n, enc_e), stale_after, args.force)
    entries.append({"kind": "encode", "file": fname,
                    "nodes": enc_n, "edges": enc_e})

    q = int(plan["score_queries"])
    fname = f"score_q{q}_n{enc_n}.hlo.txt"
    emit(os.path.join(args.out, fname),
         lambda: lower_score(spec, q, enc_n), stale_after, args.force)
    entries.append({"kind": "score", "file": fname,
                    "queries": q, "nodes": enc_n})

    manifest = {
        "version": 1,
        "name": spec.name,
        "mode": spec.mode,
        "model": {
            "entities": spec.entities,
            "relations": spec.relations,
            "embed_dim": spec.embed_dim,
            "num_bases": spec.num_bases,
            "num_layers": spec.num_layers,
            "feature_dim": spec.feature_dim,
            "dropout": spec.dropout,
        },
        "param_count": param_count(spec),
        "params": [
            {"name": ps.name, "shape": list(ps.shape), "offset": ps.offset,
             "size": ps.size, "init": ps.init,
             "fan_in": ps.fan_in, "fan_out": ps.fan_out}
            for ps in param_specs(spec)
        ],
        "entries": entries,
    }
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"[aot] wrote manifest with {len(entries)} entries -> {args.out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())

#!/usr/bin/env bash
# Run the timing benches and collect machine-readable results at the
# repo root. The epoch bench always produces BENCH_epoch.json; its
# train_epoch section (and the other benches' XLA paths) need
# `make artifacts` to have built artifacts/tiny first.
set -euo pipefail

repo_root="$(cd "$(dirname "${BASH_SOURCE[0]}")/.." && pwd)"
cd "$repo_root/rust"

echo "== optimizer bench =="
cargo bench --bench optimizer

echo "== epoch bench =="
BENCH_EPOCH_JSON="$repo_root/BENCH_epoch.json" cargo bench --bench epoch

echo "results: $repo_root/BENCH_epoch.json"

#!/usr/bin/env bash
# Run the timing benches and collect machine-readable results at the
# repo root: BENCH_optimizer.json, BENCH_epoch.json, BENCH_eval.json,
# BENCH_partition.json, BENCH_recovery.json. Each bench's synthetic
# part always runs; the XLA-backed sections (train_epoch, Evaluator,
# faulted epochs) need `make artifacts` to have built artifacts/tiny
# first.
set -euo pipefail

repo_root="$(cd "$(dirname "${BASH_SOURCE[0]}")/.." && pwd)"
cd "$repo_root/rust"

echo "== optimizer bench =="
BENCH_OPTIMIZER_JSON="$repo_root/BENCH_optimizer.json" cargo bench --bench optimizer

echo "== epoch bench =="
BENCH_EPOCH_JSON="$repo_root/BENCH_epoch.json" cargo bench --bench epoch

echo "== eval bench =="
BENCH_EVAL_JSON="$repo_root/BENCH_eval.json" cargo bench --bench eval

echo "== partition bench =="
BENCH_PARTITION_JSON="$repo_root/BENCH_partition.json" cargo bench --bench partition

echo "== recovery bench =="
BENCH_RECOVERY_JSON="$repo_root/BENCH_recovery.json" cargo bench --bench recovery

echo "results:"
for f in BENCH_optimizer.json BENCH_epoch.json BENCH_eval.json BENCH_partition.json BENCH_recovery.json; do
  echo "  $repo_root/$f"
done
